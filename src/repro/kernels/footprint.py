"""Shared footprint math for the Separable-Footprint (SF) projector model
(Long, Fessler & Balter 2010) — used by both the pure-jnp oracles in
``ref.py`` and the Pallas TPU kernels.

The SF model represents the projection of one voxel onto the detector as a
separable product of a *trapezoid* in the transaxial (u) direction and a
*rectangle* in the axial (v) direction.  Detector-pixel weights are exact
integrals of those footprints over the pixel extent, so the model captures
finite voxel and pixel sizes (the accuracy claim of the paper).
"""
from __future__ import annotations

import jax.numpy as jnp

_EPS = 1e-9


def trapezoid_cdf(t, t0, t1, t2, t3, h):
    """∫_{-inf}^{t} T(u) du for the trapezoid with breakpoints t0<=t1<=t2<=t3
    and plateau height ``h``.  Piecewise quadratic; handles degenerate
    (triangle / rectangle) cases via safe division."""
    d01 = jnp.maximum(t1 - t0, _EPS)
    d23 = jnp.maximum(t3 - t2, _EPS)
    tc1 = jnp.clip(t, t0, t1)
    tc2 = jnp.clip(t, t1, t2)
    tc3 = jnp.clip(t, t2, t3)
    rise = (tc1 - t0) ** 2 / (2.0 * d01)
    mid = tc2 - t1
    fall = ((t3 - t2) ** 2 - (t3 - tc3) ** 2) / (2.0 * d23)
    return h * (rise + mid + fall)


def trapezoid_pixel_weight(edge_lo, edge_hi, t0, t1, t2, t3, h):
    """Mean footprint value over a detector pixel [edge_lo, edge_hi]
    (units: mm of path length)."""
    return (trapezoid_cdf(edge_hi, t0, t1, t2, t3, h)
            - trapezoid_cdf(edge_lo, t0, t1, t2, t3, h)) / jnp.maximum(
                edge_hi - edge_lo, _EPS)


def parallel_footprint(uc, cos_a, sin_a, dx):
    """Transaxial trapezoid breakpoints + amplitude for *parallel* beam.

    uc: detector coordinate of the voxel center (mm), any shape.
    Returns (t0, t1, t2, t3, h)."""
    a = dx * jnp.abs(cos_a)
    b = dx * jnp.abs(sin_a)
    half_sum = 0.5 * (a + b)
    half_dif = 0.5 * jnp.abs(a - b)
    h = dx / jnp.maximum(jnp.abs(cos_a), jnp.abs(sin_a))
    return uc - half_sum, uc - half_dif, uc + half_dif, uc + half_sum, h


def rect_overlap(lo, hi, edge_lo, edge_hi):
    """Mean of a unit-height rectangle [lo, hi] over pixel [edge_lo, edge_hi]
    (dimensionless in [0, 1])."""
    ov = jnp.maximum(jnp.minimum(hi, edge_hi) - jnp.maximum(lo, edge_lo), 0.0)
    return ov / jnp.maximum(edge_hi - edge_lo, _EPS)


def fan_transaxial_footprint(x, y, cos_a, sin_a, sod, sdd, dx,
                             curved: bool = False):
    """Exact corner-projection trapezoid for a divergent (fan / cone
    transaxial) beam.

    x, y: voxel center world coordinates (broadcastable arrays).
    ``curved=False`` projects corners onto a flat detector
    (``u = sdd * q / ell``, equispaced columns); ``curved=True`` onto an
    equiangular arc (``u = sdd * atan2(q, ell)``, u = arc length).
    Returns (t0, t1, t2, t3, h, ell) where ell is the distance from the
    source plane to the voxel along the central-ray direction."""
    hx = 0.5 * dx
    taus = []
    for sx in (-hx, hx):
        for sy in (-hx, hx):
            xx = x + sx
            yy = y + sy
            ell = sod - (xx * cos_a + yy * sin_a)
            q = yy * cos_a - xx * sin_a
            if curved:
                taus.append(sdd * jnp.arctan2(q, jnp.maximum(ell, _EPS)))
            else:
                taus.append(sdd * q / jnp.maximum(ell, _EPS))
    taus = jnp.sort(jnp.stack(taus, axis=-1), axis=-1)
    t0, t1, t2, t3 = taus[..., 0], taus[..., 1], taus[..., 2], taus[..., 3]
    # Amplitude: path length of the central ray through the voxel footprint.
    ell_c = sod - (x * cos_a + y * sin_a)
    # transaxial direction of the ray through the voxel center
    rx = x - sod * cos_a
    ry = y - sod * sin_a
    rt = jnp.sqrt(rx * rx + ry * ry)
    h = dx / jnp.maximum(jnp.abs(rx), jnp.abs(ry)) * rt
    return t0, t1, t2, t3, h, ell_c


def cone_transaxial_footprint(x, y, cos_a, sin_a, sod, sdd, dx):
    """Flat-detector corner-projection trapezoid (cone transaxial part)."""
    return fan_transaxial_footprint(x, y, cos_a, sin_a, sod, sdd, dx,
                                    curved=False)

"""Public differentiable projection ops.

``forward_project`` / ``back_project`` are linear maps wired together as a
*matched pair* through ``jax.custom_vjp``:

    d/df ||forward_project(f) - y||^2  ==  2 * back_project(forward_project(f) - y)

exactly (not approximately), which is the stability requirement the paper
places on iterative/DL use.  The VJP of the forward op *is* the back op and
vice versa, so autodiff never differentiates through the projector internals.

Backends:
    * ``ref``    — pure-jnp oracles (runs everywhere; the CPU path).
    * ``pallas`` — Pallas TPU kernels (``interpret=True`` on CPU for tests).
    * ``auto``   — pallas for geometry/model pairs with a kernel, else ref.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import CTGeometry
from repro.kernels import ref

_KERNEL_TABLE = {}  # {(geom_type, model): (fp_fn, bp_fn)} — filled by kernels pkg


def register_kernel(geom_type: str, model: str, fp: Callable, bp: Callable):
    _KERNEL_TABLE[(geom_type, model)] = (fp, bp)


@functools.lru_cache(maxsize=256)
def _build_ops(geom_key: str, model: str, backend: str) -> Tuple[Callable, Callable]:
    geom = _GEOM_CACHE[geom_key]
    key = (geom.geom_type, model)
    # "auto": use the Pallas kernels on TPU; the pure-jnp path elsewhere
    # (interpret-mode Pallas is for correctness tests, not production CPU use).
    use_pallas = (backend == "pallas") or (
        backend == "auto" and key in _KERNEL_TABLE
        and jax.default_backend() == "tpu")
    if use_pallas:
        if key not in _KERNEL_TABLE:
            raise NotImplementedError(f"no pallas kernel for {key}")
        kfp, kbp = _KERNEL_TABLE[key]
        raw_fp = lambda f: kfp(f, geom)
        raw_bp = lambda p: kbp(p, geom)
    else:
        raw_fp = lambda f: ref.forward(f, geom, model)
        raw_bp = lambda p: ref.adjoint(p, geom, model)

    @jax.custom_vjp
    def fp(f):
        return raw_fp(f)

    def fp_fwd(f):
        return raw_fp(f), None

    def fp_bwd(_, g):
        return (raw_bp(g),)

    fp.defvjp(fp_fwd, fp_bwd)

    @jax.custom_vjp
    def bp(p):
        return raw_bp(p)

    def bp_fwd(p):
        return raw_bp(p), None

    def bp_bwd(_, g):
        return (raw_fp(g),)

    bp.defvjp(bp_fwd, bp_bwd)
    return fp, bp


_GEOM_CACHE: dict = {}


def get_ops(geom: CTGeometry, model: str = "sf",
            backend: str = "auto") -> Tuple[Callable, Callable]:
    """Return the (forward, back) matched differentiable pair for a geometry."""
    key = geom.key() + f"|{id(type(geom))}"
    _GEOM_CACHE[key] = geom
    return _build_ops(key, model, backend)


def _batched(op: Callable, x, vol_ndim_in: int):
    """Apply op over optional leading batch dims."""
    extra = x.ndim - vol_ndim_in
    if extra == 0:
        return op(x)
    if extra == 1:
        return jax.vmap(op)(x)
    lead = x.shape[:extra]
    flat = x.reshape((-1,) + x.shape[extra:])
    out = jax.vmap(op)(flat)
    return out.reshape(lead + out.shape[1:])


def forward_project(f, geom: CTGeometry, model: str = "sf",
                    backend: str = "auto"):
    """A @ f.  ``f``: (..., nx, ny, nz) -> (..., n_angles, n_rows, n_cols)."""
    fp, _ = get_ops(geom, model, backend)
    return _batched(fp, f, 3)


def back_project(p, geom: CTGeometry, model: str = "sf",
                 backend: str = "auto"):
    """A^T @ p.  ``p``: (..., n_angles, n_rows, n_cols) -> (..., nx, ny, nz)."""
    _, bp = get_ops(geom, model, backend)
    return _batched(bp, p, 3)

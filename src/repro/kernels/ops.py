"""Public differentiable projection ops.

``forward_project`` / ``back_project`` are linear maps wired together as a
*matched pair* through ``jax.custom_vjp``:

    d/df ||forward_project(f) - y||^2  ==  2 * back_project(forward_project(f) - y)

exactly (not approximately), which is the stability requirement the paper
places on iterative/DL use.  The VJP of the forward op *is* the back op and
vice versa, so autodiff never differentiates through the projector internals.

Backends:
    * ``ref``    — pure-jnp oracles (runs everywhere; the CPU path).
    * ``pallas`` — Pallas TPU kernels (``interpret=True`` on CPU for tests).
      Parallel, fan, cone, and (axial-frame) modular SF pairs are all
      Pallas matched pairs — each registered BP is the exact transpose of
      its FP kernel, so training steps stay on-kernel end to end for every
      geometry, including helical trajectories.
    * ``auto``   — pallas for geometry/model pairs with a kernel whose
      ``supports`` gate (if any) accepts the geometry, else ref.

Batching: kernels may register *batched* variants that fold a leading batch
dimension into the TPU lane axis (see ``fp_par.py``); when present these
replace the per-sample ``jax.vmap`` over the ``pallas_call`` — the vmap path
remains the fallback for the ref backend and batch-unaware kernels.

Modes: a kernel entry may additionally register an approximate *packed*
pair (cone: the lane-packed axial pre-resample, ``fp_cone.fp_cone_packed``)
guarded by a per-geometry predicate.  ``mode="exact"`` always uses the
exact pair, ``mode="packed"`` forces the packed one, and the default
``mode="auto"`` dispatches packed only when the registered gate
(``tune.packed_cone_ok`` — the derived error bound under tolerance)
accepts the geometry.  Both pairs are matched custom_vjp pairs, so
gradients stay exactly consistent in every mode.

Precision: every entry point takes ``compute_dtype`` ("bfloat16" |
"float32" | None = follow the input dtype) implementing the bf16-tile /
f32-accumulate policy of :mod:`repro.kernels.precision`; the ref backend
applies the matching quantize-data-only policy so oracles stay
dtype-matched.

Specs: every entry point canonically takes a
:class:`repro.core.spec.ProjectorSpec` — the frozen consolidation of
``(geom, model, backend, mode, compute_dtype, config)``.  Geometry-first
calls (``get_ops(geom, model=...)``) keep working through the deprecation
shim in :mod:`repro.core.spec` (one warning per entry point per process).

Tile/block sizes come from :class:`repro.kernels.tune.KernelConfig`; pass
``config=`` to pin one explicitly (it becomes part of the op-cache key, so a
fixed config never retraces).  The op cache is a bounded LRU keyed on
``spec.cache_key()`` — geometry *content* (``CTGeometry.canonical_hash()``)
plus model/backend/config/resolved-mode and the dtype pair (normalized
compute policy, input dtype) — so equal geometries share ops and evicted
entries release both the traced functions and the geometry they close over.
:func:`cache_stats` exposes size/hit/miss counters; the serving layer's
warm-path guarantee ("a primed server never compiles on the request path")
is asserted against them.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import CTGeometry
from repro.core.spec import ProjectorSpec, as_spec
from repro.kernels import precision, ref, tune


class _KernelEntry(NamedTuple):
    """A registered Pallas kernel pair (+ optional lane-packed batched pair
    and, for cone, an approximate *packed* pair gated by ``packed_ok``)."""
    fp: Callable
    bp: Callable
    fp_batched: Optional[Callable] = None
    bp_batched: Optional[Callable] = None
    fp_packed: Optional[Callable] = None
    bp_packed: Optional[Callable] = None
    packed_ok: Optional[Callable] = None     # geom -> bool (mode="auto" gate)
    supports: Optional[Callable] = None      # geom -> bool (kernel coverage)


# {(geom_type, model): _KernelEntry} — filled by the kernels package on import
_KERNEL_TABLE: Dict[Tuple[str, str], _KernelEntry] = {}

_MODES = ("auto", "exact", "packed")


def register_kernel(geom_type: str, model: str, fp: Callable, bp: Callable,
                    fp_batched: Optional[Callable] = None,
                    bp_batched: Optional[Callable] = None,
                    fp_packed: Optional[Callable] = None,
                    bp_packed: Optional[Callable] = None,
                    packed_ok: Optional[Callable] = None,
                    supports: Optional[Callable] = None):
    """Register a Pallas kernel pair.  All callables take
    ``(array, geom, config=KernelConfig|None, compute_dtype=None)`` — the
    precision policy of kernels/precision.py is part of the registration
    contract; the batched variants accept a leading batch dimension and fold
    it into the kernel (lane packing or view-axis folding) instead of
    requiring an outer vmap.

    ``fp_packed``/``bp_packed`` register an *approximate* matched pair (the
    lane-packed cone pre-resample) selected by ``mode="packed"`` or by
    ``mode="auto"`` when ``packed_ok(geom)`` holds (the per-geometry error
    bound stays under tolerance).

    ``supports`` restricts the entry to a geometry subclass (modular: axial
    frames): ``backend="auto"`` falls back to the ref oracle when it
    rejects a geometry; an explicit ``backend="pallas"`` still dispatches
    and lets the kernel raise its own informative error."""
    _KERNEL_TABLE[(geom_type, model)] = _KernelEntry(
        fp, bp, fp_batched, bp_batched, fp_packed, bp_packed, packed_ok,
        supports)


class Ops(NamedTuple):
    """Matched differentiable op bundle for one (geometry, model, backend)."""
    fp: Callable
    bp: Callable
    fp_batched: Optional[Callable]
    bp_batched: Optional[Callable]
    config: Optional[tune.KernelConfig]


def _make_pair(raw_fp: Callable, raw_bp: Callable) -> Tuple[Callable, Callable]:
    """Wire (A, A^T) together so each is the other's VJP."""

    @jax.custom_vjp
    def fp(f):
        return raw_fp(f)

    def fp_fwd(f):
        return raw_fp(f), None

    def fp_bwd(_, g):
        return (raw_bp(g),)

    fp.defvjp(fp_fwd, fp_bwd)

    @jax.custom_vjp
    def bp(p):
        return raw_bp(p)

    def bp_fwd(p):
        return raw_bp(p), None

    def bp_bwd(_, g):
        return (raw_fp(g),)

    bp.defvjp(bp_fwd, bp_bwd)
    return fp, bp


def _use_pallas(geom: CTGeometry, model: str, backend: str) -> bool:
    # "auto": use the Pallas kernels on TPU; the pure-jnp path elsewhere
    # (interpret-mode Pallas is for correctness tests, not production CPU use).
    if backend == "pallas":
        return True
    entry = _KERNEL_TABLE.get((geom.geom_type, model))
    return (backend == "auto" and entry is not None
            and (entry.supports is None or entry.supports(geom))
            and jax.default_backend() == "tpu")


def _resolve_mode(geom: CTGeometry, model: str, mode: str,
                  use_pallas: bool) -> str:
    """Collapse ``mode`` to the concrete pair that will dispatch
    ("exact" | "packed")."""
    if mode not in _MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {_MODES}")
    if mode == "exact":
        return "exact"
    entry = _KERNEL_TABLE.get((geom.geom_type, model))
    has_packed = (use_pallas and entry is not None
                  and entry.fp_packed is not None
                  and entry.bp_packed is not None)
    if mode == "packed":
        if not has_packed:
            raise NotImplementedError(
                f"mode='packed' needs a registered packed kernel pair for "
                f"({geom.geom_type}, {model}) on the pallas backend")
        return "packed"
    # "auto": packed only when the registered gate accepts the geometry
    # (the per-geometry error bound is under tolerance).
    if has_packed and entry.packed_ok is not None and entry.packed_ok(geom):
        return "packed"
    return "exact"


def resolve_mode(geom, model: str = "sf", backend: str = "auto",
                 mode: str = "auto") -> str:
    """The concrete kernel mode ("exact" | "packed") that
    ``forward_project``/``back_project`` would dispatch for these arguments —
    exposed so callers (and tests) can observe the ``mode="auto"`` policy
    without probing numerics.  Accepts a ProjectorSpec or a geometry (this
    is a read-only probe, so the geometry form is not deprecated here)."""
    if isinstance(geom, ProjectorSpec):
        geom, model, backend, mode = (geom.geom, geom.model, geom.backend,
                                      geom.mode)
    return _resolve_mode(geom, model, mode, _use_pallas(geom, model, backend))


def _build(geom: CTGeometry, model: str, backend: str,
           config: Optional[tune.KernelConfig], use_pallas: bool,
           mode: str, compute_dtype) -> Ops:
    fp_b = bp_b = None
    cdt = compute_dtype
    if use_pallas:
        key = (geom.geom_type, model)
        if key not in _KERNEL_TABLE:
            raise NotImplementedError(f"no pallas kernel for {key}")
        entry = _KERNEL_TABLE[key]
        # An explicit user config is pinned; config=None flows through so
        # the kernel entry points resolve against the *actual* input batch
        # size and dtype (batch-/dtype-aware shape classes and autotune).
        if mode == "packed":
            # The packed pair lane-packs batches natively (3D and 4D inputs
            # through the same entry points).
            raw_fp = lambda f: entry.fp_packed(f, geom, config=config,
                                               compute_dtype=cdt)
            raw_bp = lambda p: entry.bp_packed(p, geom, config=config,
                                               compute_dtype=cdt)
            fp_b, bp_b = _make_pair(raw_fp, raw_bp)
        else:
            raw_fp = lambda f: entry.fp(f, geom, config=config,
                                        compute_dtype=cdt)
            raw_bp = lambda p: entry.bp(p, geom, config=config,
                                        compute_dtype=cdt)
            if entry.fp_batched is not None and entry.bp_batched is not None:
                fp_b, bp_b = _make_pair(
                    lambda f: entry.fp_batched(f, geom, config=config,
                                               compute_dtype=cdt),
                    lambda p: entry.bp_batched(p, geom, config=config,
                                               compute_dtype=cdt))
    else:
        raw_fp = lambda f: ref.forward(f, geom, model, dtype=cdt)
        raw_bp = lambda p: ref.adjoint(p, geom, model, dtype=cdt)
    fp, bp = _make_pair(raw_fp, raw_bp)
    return Ops(fp, bp, fp_b, bp_b, config)


# Bounded LRU over op bundles.  Keys are ``spec.cache_key()`` — geometry
# *content* (not object identity), so two equal geometries share one entry,
# and eviction drops the traced ops together with the geometry captured in
# their closures.
_OPS_CACHE: "OrderedDict[Tuple, Ops]" = OrderedDict()
_OPS_CACHE_SIZE = 256
_CACHE_HITS = 0
_CACHE_MISSES = 0


def _get_bundle(spec: ProjectorSpec, in_dtype=None) -> Ops:
    global _CACHE_HITS, _CACHE_MISSES
    if spec.shard is not None:
        raise ValueError(
            "spec carries a ShardSpec — the local op cache cannot realize "
            "a sharded layout; build DistributedProjector(spec, mesh) "
            "(repro.core.distributed), or drop the shard with "
            "spec.replace(shard=None) for single-device ops")
    geom = spec.geom
    use_pallas = _use_pallas(geom, spec.model, spec.backend)
    rmode = _resolve_mode(geom, spec.model, spec.mode, use_pallas)
    # The cache is keyed on the *user's* config value: None means "let the
    # kernel resolve per call" (note: re-registering configs after a bundle
    # is cached requires clear_cache() to take effect on the None key).
    # Mode is keyed on the *resolved* value so "auto" and an explicit
    # "packed"/"exact" share one bundle when they dispatch the same pair.
    # Dtype is part of the content key: the normalized compute policy (a
    # spec field) plus the input dtype the bundle was first applied to — a
    # cdt=None bundle follows its input's dtype, so f32 and bf16 callers
    # must not share traced closures (and even fixed-cdt bundles key the
    # input dtype so the output dtype stays caller-consistent).
    idt = None if in_dtype is None else jnp.dtype(in_dtype).name
    key = spec.cache_key(rmode, idt)
    hit = _OPS_CACHE.get(key)
    if hit is not None:
        _CACHE_HITS += 1
        _OPS_CACHE.move_to_end(key)
        return hit
    _CACHE_MISSES += 1
    bundle = _build(geom, spec.model, spec.backend, spec.config, use_pallas,
                    rmode, spec.compute_dtype)
    _OPS_CACHE[key] = bundle
    while len(_OPS_CACHE) > _OPS_CACHE_SIZE:
        _OPS_CACHE.popitem(last=False)
    return bundle


def clear_cache() -> None:
    """Drop every cached op bundle (e.g. after re-registering configs)."""
    _OPS_CACHE.clear()


def cache_stats() -> Dict[str, int]:
    """Op-cache observability: ``{"size", "hits", "misses"}``.

    The serving layer's warm-path guarantee is checked against these — on a
    warm server, request traffic must add zero entries and zero misses."""
    return {"size": len(_OPS_CACHE), "hits": _CACHE_HITS,
            "misses": _CACHE_MISSES}


def get_ops(spec_or_geom, model: str = "sf", backend: str = "auto",
            config: Optional[tune.KernelConfig] = None,
            mode: str = "auto", compute_dtype=None) -> Tuple[Callable, Callable]:
    """Return the (forward, back) matched differentiable pair for a spec.

    Canonical form: ``get_ops(ProjectorSpec(geom, ...))``.  The legacy
    geometry-first form (``get_ops(geom, model=..., ...)``) still works via
    the deprecation shim in :mod:`repro.core.spec`.

    ``mode`` selects between the exact kernels and an approximate *packed*
    pair where one is registered (cone): "exact" forces the exact pair,
    "packed" forces the packed pair (error ignored), "auto" uses packed only
    when the per-geometry error bound is under tolerance
    (``tune.packed_cone_ok``).  The packed pair is matched (exact transpose
    of itself), so gradients stay consistent in every mode.

    ``compute_dtype`` sets the kernels' tile precision ("bfloat16" |
    "float32"; None follows the input dtype) — accumulation is always f32
    and outputs keep the caller's dtype (see kernels/precision.py).

    Repeated calls with an equal spec return the *same* function objects, so
    jit caches built around them never retrace."""
    spec = as_spec(spec_or_geom, "get_ops", model=model, backend=backend,
                   mode=mode, compute_dtype=compute_dtype, config=config)
    bundle = _get_bundle(spec)
    return bundle.fp, bundle.bp


def _batched(op: Callable, x, vol_ndim_in: int):
    """Apply op over optional leading batch dims (generic vmap fallback)."""
    extra = x.ndim - vol_ndim_in
    if extra == 0:
        return op(x)
    if extra == 1:
        return jax.vmap(op)(x)
    lead = x.shape[:extra]
    flat = x.reshape((-1,) + x.shape[extra:])
    out = jax.vmap(op)(flat)
    return out.reshape(lead + out.shape[1:])


def _apply(op: Callable, op_batched: Optional[Callable], x, ndim_in: int):
    """Dispatch to the kernel's native batched path when one is registered;
    vmap per sample otherwise."""
    extra = x.ndim - ndim_in
    if extra == 0:
        return op(x)
    if op_batched is None:
        return _batched(op, x, ndim_in)
    lead = x.shape[:extra]
    flat = x if extra == 1 else x.reshape((-1,) + x.shape[extra:])
    out = op_batched(flat)
    return out if extra == 1 else out.reshape(lead + out.shape[1:])


def forward_project(f, spec_or_geom, model: str = "sf",
                    backend: str = "auto",
                    config: Optional[tune.KernelConfig] = None,
                    mode: str = "auto", compute_dtype=None):
    """A @ f.  ``f``: (..., nx, ny, nz) -> (..., n_angles, n_rows, n_cols).

    Canonical form: ``forward_project(f, ProjectorSpec(geom, ...))``; the
    geometry-first form survives via the deprecation shim."""
    spec = as_spec(spec_or_geom, "forward_project", model=model,
                   backend=backend, mode=mode, compute_dtype=compute_dtype,
                   config=config)
    b = _get_bundle(spec, in_dtype=f.dtype)
    return _apply(b.fp, b.fp_batched, f, 3)


def back_project(p, spec_or_geom, model: str = "sf",
                 backend: str = "auto",
                 config: Optional[tune.KernelConfig] = None,
                 mode: str = "auto", compute_dtype=None):
    """A^T @ p.  ``p``: (..., n_angles, n_rows, n_cols) -> (..., nx, ny, nz).

    Canonical form: ``back_project(p, ProjectorSpec(geom, ...))``; the
    geometry-first form survives via the deprecation shim."""
    spec = as_spec(spec_or_geom, "back_project", model=model,
                   backend=backend, mode=mode, compute_dtype=compute_dtype,
                   config=config)
    b = _get_bundle(spec, in_dtype=p.dtype)
    return _apply(b.bp, b.bp_batched, p, 3)

"""Pallas TPU kernels: fan-beam Separable-Footprint forward/back projection.

The fan beam is the cone beam with the axial part collapsed: each detector
row is an independent in-plane fan of the matching z-slab, so the axial
(z -> detector row) footprint is the *parallel-beam* angle-independent
rectangle overlap and is hoisted out of the kernel as one einsum — exactly
like ``fp_par.py``.  What remains inside the kernel is the cone kernel's
transaxial *corner-projection* trapezoid (``fp_cone.py``), evaluated per
window element with no per-lane axial resample.

Because the lane axis is purely data-parallel again, **lane packing applies
directly**: batched inputs fold ``batch x n_rows`` detector rows onto the
128-wide axis instead of vmapping the ``pallas_call`` — the fan beam is the
"pre-collapsed axial" limit of the cone beam, and the packed cone pair
(``fp_cone.fp_cone_packed``) reuses ``_fp_core``/``_bp_core`` below with a
central-magnification axial pre-resample to lane-pack small-cone-angle
batches the same way.

Detector models (``geom.detector_type``):

* ``flat``   — equispaced columns, corner projection ``u = sdd * q / ell``;
* ``curved`` — equiangular arc, ``u`` is arc length and the corner
  projection is ``u = sdd * atan2(q, ell)``.  The window-start inversion
  uses ``tan(u / sdd)`` (the geometry validator guarantees |u|/sdd < pi/2).

Both kernels share the weight math; the backprojector is the exact
transpose of the forward (same corner-projected breakpoints, transposed
contraction), so the registered pair is *matched* in the paper's sense and
fan training steps stay on-kernel end to end (as do cone steps, whose BP in
``fp_cone.py`` transposes the per-element axial resample as well).

Tile/block sizes come from :mod:`repro.kernels.tune` (``KernelConfig``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.geometry import CTGeometry
from repro.kernels import precision, tune
from repro.kernels.footprint import trapezoid_pixel_weight
from repro.kernels.fp_cone import (_corner_trapezoid, _mag_bounds,
                                   _u_window_size_div, _view_params_cone)
from repro.kernels.fp_par import _interpret, _pad_views, _round_up
from repro.kernels.ref import _z_overlap_matrix

_EPS = 1e-9


def _curved_stretch(geom: CTGeometry) -> float:
    """For the curved detector du/dgi shrinks by cos^2(gamma) at the fan
    edge, widening the gathered-index window per u-tile by 1/cos^2."""
    if geom.detector_type != "curved":
        return 1.0
    umax = (geom.n_cols - 1) / 2.0 * geom.pixel_width + abs(geom.center_col)
    gmax = min(umax / geom.sdd, math.pi / 2 - 1e-3)
    return 1.0 / (math.cos(gmax) ** 2)


def _window_size_fan(geom: CTGeometry, bu: int, ng: int) -> int:
    """Static bound on the gathered-axis window covering one u-tile (same
    construction as the cone kernel, plus the curved-detector stretch)."""
    du, dx = geom.pixel_width, geom.vol.dx
    mag_min, mag_max = _mag_bounds(geom)
    stretch = _curved_stretch(geom)
    span = bu * du * math.sqrt(2.0) * stretch / (dx * mag_min)
    margin = 2.0 * (math.sqrt(2.0) * dx * mag_max + du) / (dx * mag_min) + 4.0
    w = int(math.ceil(span + 2 * margin)) + 2
    return min(_round_up(max(w, 8), 8), ng)


def _fan_trapezoid(P, gi, q0, l0, lif, sdd, dxv, curved):
    """Shared weight math (used by FP and BP identically, so the pair is an
    exact transpose): corner-projected trapezoid breakpoints + amplitude for
    gathered indices ``gi`` (broadcast shape).  Thin wrapper over the cone
    kernels' ``_corner_trapezoid`` (``P`` is the 20-float per-view parameter
    row of ``fp_cone._view_params_cone``); fan drops the squared ray length
    used by the cone axial obliquity."""
    t0, t1, t2, t3, h, _rt2 = _corner_trapezoid(P, gi, q0, l0, lif, sdd,
                                                dxv, curved)
    return t0, t1, t2, t3, h


# --------------------------------------------------------------------------- #
# Forward kernel
# --------------------------------------------------------------------------- #
def _fp_fan_kernel(params_ref,          # SMEM (n_views, 20)
                   g_ref,               # VMEM (NG, 1, bv) volume line
                   out_ref,             # VMEM (ba, bu, bv) sino tile
                   *, W: int, u0: float, du: float, sdd: float, dxv: float,
                   ng: int, bu: int, bv: int, ba: int, curved: bool):
    """One program: for ``ba`` consecutive views, contract a (bu, W)
    corner-projection footprint tile against the same (W, bv) volume window
    on the MXU.  Identical structure to ``fp_par._fp_kernel`` — the lane
    axis carries packed ``batch x n_rows`` rows — with the parallel affine
    ``uc`` replaced by the divergent corner projection."""
    ab = pl.program_id(0)
    ub = pl.program_id(1)
    li = pl.program_id(3)

    @pl.when(li == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    u_first = u0 + (ub * bu) * du
    u_last = u_first + (bu - 1) * du

    for j in range(ba):
        a = ab * ba + j
        P = [params_ref[a, i] for i in range(20)]
        Aq, Bq, Cq, Al, Bl, Cl = P[:6]
        q0 = Bq * lif + Cq
        l0 = Bl * lif + Cl

        # window start: invert the center projection u(gi)
        def gi_of(u):
            if curved:
                t = jnp.tan(u / sdd)
                den = Aq - t * Al
                den = jnp.where(jnp.abs(den) > 1e-6,
                                den, jnp.where(den >= 0, 1e-6, -1e-6))
                return (t * l0 - q0) / den
            den = sdd * Aq - u * Al
            den = jnp.where(jnp.abs(den) > 1e-6,
                            den, jnp.where(den >= 0, 1e-6, -1e-6))
            return (u * l0 - sdd * q0) / den

        g1, g2 = gi_of(u_first), gi_of(u_last)
        start = jnp.floor(jnp.minimum(g1, g2)).astype(jnp.int32) - (
            W - jnp.abs(jnp.ceil(g2 - g1)).astype(jnp.int32)) // 2
        start = jnp.clip(start, 0, max(ng - W, 0))

        win = g_ref[pl.ds(start, W), 0, :]                     # (W, bv)
        gi = start.astype(jnp.float32) + jax.lax.broadcasted_iota(
            jnp.float32, (1, W), 1)                            # (1, W)
        t0, t1, t2, t3, h = _fan_trapezoid(P, gi, q0, l0, lif, sdd, dxv,
                                           curved)

        uk = u_first + du * jax.lax.broadcasted_iota(jnp.float32, (bu, 1), 0)
        el = uk - du / 2.0                                     # (bu, 1)
        wgt = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
        precision.store_tile(out_ref, j, jax.lax.dot_general(
            precision.cast_like(wgt, win), win, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))


def _run_fp_group(g, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bu: int, bv: int, ba: int = 1):
    """g: (nx, ny, NVp) volume with the lane axis already padded to a bv
    multiple (NVp lanes = packed batch * n_rows)."""
    if params.shape[0] == 0:
        raise ValueError(
            "empty view group reached the fan Pallas kernel; callers "
            "(_fp_core/_bp_core) must skip groups with no views")
    if not gathered_x:
        g = jnp.swapaxes(g, 0, 1)
    ng, nl, nvp = g.shape
    na = params.shape[0]
    params, _, ba = _pad_views(params, ba)     # padded views dropped after
    nap = params.shape[0]
    nup = _round_up(geom.n_cols, bu)
    W = _window_size_fan(geom, bu, ng)
    grid = (nap // ba, nup // bu, nvp // bv, nl)
    kernel = functools.partial(
        _fp_fan_kernel, W=W, u0=float(geom.u_coords()[0]),
        du=geom.pixel_width, sdd=geom.sdd, dxv=geom.vol.dx, ng=ng,
        bu=bu, bv=bv, ba=ba, curved=geom.detector_type == "curved")
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((ng, 1, bv),
                                   lambda ab, ub, vb, l, *_: (0, l, vb))],
            out_specs=pl.BlockSpec((ba, bu, bv),
                                   lambda ab, ub, vb, l, *_: (ab, ub, vb)),
        ),
        # f32 cross-step accumulator regardless of the tile dtype.
        out_shape=jax.ShapeDtypeStruct((nap, nup, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), g)
    return out[:na]


def _fp_core(g, geom: CTGeometry, cfg: tune.KernelConfig):
    """g: (nx, ny, NV) lane-packed axial-footprint volume.  Returns the
    u-major sinogram (n_angles, n_cols, NV)."""
    nv_lanes = g.shape[2]
    nvp = _round_up(nv_lanes, cfg.bv)
    g = jnp.pad(g, ((0, 0), (0, 0), (0, nvp - nv_lanes)))
    px, py, order = _view_params_cone(geom)
    outs = []
    if px.shape[0]:
        outs.append(_run_fp_group(g, px, geom, True, cfg.bu, cfg.bv, cfg.ba))
    if py.shape[0]:
        outs.append(_run_fp_group(g, py, geom, False, cfg.bu, cfg.bv, cfg.ba))
    out = jnp.concatenate(outs, axis=0)                        # (na, NUp, NVp)
    out = out[:, :geom.n_cols, :nv_lanes]
    inv = np.argsort(order)
    return out[inv]


def fp_fan_sf_pallas(f, geom: CTGeometry, bu: Optional[int] = None,
                     bv: Optional[int] = None, ba: Optional[int] = None,
                     config: Optional[tune.KernelConfig] = None,
                     compute_dtype=None):
    """f: (nx, ny, nz) -> sino (n_angles, n_rows, n_cols), or lane-packed
    batched f: (batch, nx, ny, nz) -> (batch, n_angles, n_rows, n_cols).
    ``compute_dtype`` selects the tile dtype at the VMEM boundary (None =
    follow ``f.dtype``); accumulation stays f32, output is ``f.dtype``."""
    if geom.geom_type != "fan":
        raise ValueError(f"fp_fan_sf_pallas needs a fan geometry, got "
                         f"geom_type={geom.geom_type!r}; dispatch through "
                         f"get_ops/forward_project for auto kernel selection")
    if f.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D volume, got {f.shape}")
    batch = f.shape[0] if f.ndim == 4 else 1
    out_dtype = f.dtype
    cdt = precision.resolve(compute_dtype, f.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bu=bu, bv=bv, ba=ba)
    Fz = jnp.asarray(_z_overlap_matrix(geom))                  # (nz, nv)
    if f.ndim == 3:
        g = jnp.einsum("xyz,zv->xyv", f, Fz)                   # axial footprint
        g = precision.cast_in(g, cdt)
        out = _fp_core(g, geom, cfg)                           # (na, nu, nv)
        return jnp.swapaxes(out, 1, 2).astype(out_dtype)       # (na, nv, nu)
    g = jnp.einsum("bxyz,zv->xybv", f, Fz)                     # (nx, ny, B, nv)
    g = g.reshape(geom.vol.nx, geom.vol.ny, batch * geom.n_rows)
    g = precision.cast_in(g, cdt)
    out = _fp_core(g, geom, cfg)                               # (na, nu, B*nv)
    out = out.reshape(geom.n_angles, geom.n_cols, batch, geom.n_rows)
    return jnp.transpose(out, (2, 0, 3, 1)).astype(out_dtype)


# --------------------------------------------------------------------------- #
# Backprojection kernel (exact transpose)
# --------------------------------------------------------------------------- #
def _bp_fan_kernel(params_ref,          # SMEM (n_views, 20)
                   q_ref,               # VMEM (bab, NU, bv) sino stripes
                   out_ref,             # VMEM (bs*bg, 1, bv) volume tile
                   *, Wu: int, u0: float, du: float, sdd: float, dxv: float,
                   nu: int, bg: int, bv: int, bab: int, bs: int,
                   curved: bool):
    """One program: accumulate ``bab`` views into ``bs`` consecutive
    (bg, bv) volume sub-tiles — the exact transpose of ``_fp_fan_kernel``
    (same corner-projected breakpoints, transposed contraction).  Stripe
    reuse (bs > 1) serves ``bs`` gathered sub-tiles per stripe residency;
    see ``fp_par._bp_kernel``."""
    gb = pl.program_id(0)
    li = pl.program_id(1)
    ab = pl.program_id(3)

    @pl.when(ab == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    subs = [jnp.zeros((bg, bv), jnp.float32) for _ in range(bs)]
    for j in range(bab):
        a = ab * bab + j
        P = [params_ref[a, i] for i in range(20)]
        Aq, Bq, Cq, Al, Bl, Cl = P[:6]
        q0 = Bq * lif + Cq
        l0 = Bl * lif + Cl

        def uc_of(gi):
            qg = Aq * gi + q0
            lg = jnp.maximum(Al * gi + l0, _EPS)
            if curved:
                return sdd * jnp.arctan2(qg, lg)
            return sdd * qg / lg

        for sj in range(bs):
            gi0 = (gb * bs + sj) * bg
            gi_abs = gi0 + jax.lax.broadcasted_iota(jnp.float32, (bg, 1), 0)
            uc_a = uc_of(gi0.astype(jnp.float32))
            uc_b = uc_of((gi0 + bg - 1).astype(jnp.float32))
            ustart = jnp.floor(
                (jnp.minimum(uc_a, uc_b) - u0) / du).astype(jnp.int32) - (
                Wu - jnp.abs(jnp.ceil((uc_b - uc_a) / du)).astype(
                    jnp.int32)) // 2
            ustart = jnp.clip(ustart, 0, max(nu - Wu, 0))

            qwin = q_ref[j, pl.ds(ustart, Wu), :]              # (Wu, bv)
            t0, t1, t2, t3, h = _fan_trapezoid(P, gi_abs, q0, l0, lif, sdd,
                                               dxv, curved)    # (bg, 1)
            uk = u0 + (ustart.astype(jnp.float32)
                       + jax.lax.broadcasted_iota(
                           jnp.float32, (1, Wu), 1)) * du
            el = uk - du / 2.0                                 # (1, Wu)
            wgt = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
            subs[sj] = subs[sj] + jax.lax.dot_general(
                precision.cast_like(wgt, qwin), qwin, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    acc = subs[0] if bs == 1 else jnp.concatenate(subs, axis=0)
    precision.store_tile(out_ref, (slice(None), 0, slice(None)), acc)


def _run_bp_group(q, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bg: int, bv: int, bab: int = 1, bs: int = 1):
    """q: (na_group, NUp, NVp) u-major sino slice for this view group.
    Returns the gathered-axis-major volume accumulator (NG, NL, NVp)."""
    ng, nl = ((geom.vol.nx, geom.vol.ny) if gathered_x
              else (geom.vol.ny, geom.vol.nx))
    na, nup, nvp = q.shape
    params, q, bab = _pad_views(params, bab, q)
    nap = params.shape[0]
    bs = max(1, min(bs, max(1, ng // bg)))    # don't block past the axis
    bstr = bg * bs                            # gathered voxels per program
    ngp = _round_up(ng, bstr)
    Wu = _u_window_size_div(geom, bg, nup)
    grid = (ngp // bstr, nl, nvp // bv, nap // bab)
    kernel = functools.partial(
        _bp_fan_kernel, Wu=Wu, u0=float(geom.u_coords()[0]),
        du=geom.pixel_width, sdd=geom.sdd, dxv=geom.vol.dx, nu=nup,
        bg=bg, bv=bv, bab=bab, bs=bs, curved=geom.detector_type == "curved")
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((bab, nup, bv),
                                   lambda gb, l, vb, ab, *_: (ab, 0, vb))],
            out_specs=pl.BlockSpec((bstr, 1, bv),
                                   lambda gb, l, vb, ab, *_: (gb, l, vb)),
        ),
        # f32 cross-step accumulator regardless of the stripe dtype.
        out_shape=jax.ShapeDtypeStruct((ngp, nl, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), q)
    return out[:ng]


def _bp_core(q, geom: CTGeometry, cfg: tune.KernelConfig):
    """q: (n_angles, n_cols, NV) u-major lane-packed sinogram.  Returns the
    transaxial volume accumulator (nx, ny, NV)."""
    nv_lanes = q.shape[2]
    nvp = _round_up(nv_lanes, cfg.bv)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, nvp - nv_lanes)))
    px, py, order = _view_params_cone(geom)
    q = q[order]                                               # group-major
    nax = px.shape[0]
    acc = jnp.zeros((geom.vol.nx, geom.vol.ny, nvp), jnp.float32)
    if nax:
        acc = acc + _run_bp_group(q[:nax], px, geom, True,
                                  cfg.bg, cfg.bv, cfg.bab, cfg.bs)
    if py.shape[0]:
        accy = _run_bp_group(q[nax:], py, geom, False,
                             cfg.bg, cfg.bv, cfg.bab, cfg.bs)
        acc = acc + jnp.swapaxes(accy, 0, 1)
    return acc[:, :, :nv_lanes]


def bp_fan_sf_pallas(sino, geom: CTGeometry, bg: Optional[int] = None,
                     bv: Optional[int] = None, bab: Optional[int] = None,
                     bs: Optional[int] = None,
                     config: Optional[tune.KernelConfig] = None,
                     compute_dtype=None):
    """sino: (n_angles, n_rows, n_cols) -> volume (nx, ny, nz), or
    lane-packed batched sino: (batch, ...) -> (batch, nx, ny, nz).
    Exact transpose of ``fp_fan_sf_pallas`` (incl. the batched path).
    ``compute_dtype`` selects the stripe dtype at the VMEM boundary; ``bs``
    overrides the stripe-reuse blocking factor."""
    if geom.geom_type != "fan":
        raise ValueError(f"bp_fan_sf_pallas needs a fan geometry, got "
                         f"geom_type={geom.geom_type!r}; dispatch through "
                         f"get_ops/back_project for auto kernel selection")
    if sino.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D sinogram, got {sino.shape}")
    batch = sino.shape[0] if sino.ndim == 4 else 1
    out_dtype = sino.dtype
    cdt = precision.resolve(compute_dtype, sino.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bg=bg, bv=bv, bab=bab, bs=bs)
    Fz = jnp.asarray(_z_overlap_matrix(geom))                  # (nz, nv)
    if sino.ndim == 3:
        q = jnp.swapaxes(sino, 1, 2)                           # (na, nu, nv)
        q = precision.cast_in(q, cdt)
        acc = _bp_core(q, geom, cfg)                           # (nx, ny, nv)
        return jnp.einsum("xyv,zv->xyz", acc, Fz).astype(out_dtype)
    q = jnp.transpose(sino, (1, 3, 0, 2))                      # (na, nu, B, nv)
    q = q.reshape(geom.n_angles, geom.n_cols, batch * geom.n_rows)
    q = precision.cast_in(q, cdt)
    acc = _bp_core(q, geom, cfg)                               # (nx, ny, B*nv)
    acc = acc.reshape(geom.vol.nx, geom.vol.ny, batch, geom.n_rows)
    return jnp.einsum("xybv,zv->bxyz", acc, Fz).astype(out_dtype)


def register():
    from repro.kernels import ops
    ops.register_kernel("fan", "sf", fp_fan_sf_pallas, bp_fan_sf_pallas,
                        fp_batched=fp_fan_sf_pallas,
                        bp_batched=bp_fan_sf_pallas)

"""Mixed-precision policy shared by every projector kernel pair.

One idiom, applied uniformly (see docs/KERNELS.md "Precision policy"):

* **Tiles** — the dominant HBM streams (volume lines for FP, sinogram
  stripes for BP) are cast to the *compute dtype* at the ``pallas_call``
  boundary, so VMEM blocks and DMA traffic shrink 2x at bf16.
* **Weights** — SF footprint weights are always *derived* in float32 from
  SMEM scalars (coordinates at bf16's 8-bit mantissa would corrupt the
  trapezoid geometry), then cast to the tile dtype right before the MXU
  contraction so both operands match (:func:`cast_like`).
* **Accumulation** — every contraction carries
  ``preferred_element_type=jnp.float32`` and every kernel output buffer is
  float32; partial sums never round through bf16.  The caller's dtype is
  restored only once, on the final result (:func:`store_tile` is the single
  point where an accumulator meets an output ref).

The policy is threaded as ``compute_dtype`` from ``Projector`` / ``get_ops``
through ``ops.py`` into each kernel entry point; ``None`` means "follow the
input's dtype" (f32 in -> f32 tiles, bf16 in -> bf16 tiles + f32 accum).
"""
from __future__ import annotations

import jax.numpy as jnp

# bfloat16 has an 8-bit significand (incl. the hidden bit): one quantization
# step is 2^-8 relative.
BF16_EPS = 2.0 ** -8

# Documented relative error bound of a bf16-tile / f32-accumulate projection
# against the f32 oracle (max-abs error over max-abs reference).  Tile and
# weight quantization each contribute <= BF16_EPS relative per product and
# the SF weights are non-negative, so errors grow sublinearly under the f32
# accumulation; 12x covers the observed worst case with >2x margin.
BF16_FP_REL_BOUND = 12 * BF16_EPS            # ~= 0.047

# Matched-pair dot-test tolerance at bf16: the pair is still an exact
# transpose of the *quantized* operator, but the forward path quantizes the
# axially-convolved volume while the adjoint path quantizes the sinogram, so
# <Ax, y> and <x, A'y> differ by O(BF16_EPS) relative.  5x margin.
BF16_DOT_TOL = 5 * BF16_EPS                  # ~= 0.02

_SUPPORTED = ("float32", "bfloat16")
_ALIASES = {"f32": "float32", "fp32": "float32", "bf16": "bfloat16"}


def normalize(compute_dtype):
    """Canonicalize a compute-dtype policy value.

    ``None`` / ``"auto"`` -> ``None`` (follow the input dtype); otherwise the
    canonical jnp dtype name (``"float32"`` | ``"bfloat16"``).  Accepts
    strings, numpy/jnp dtypes and scalar types; raises ``ValueError`` for
    anything outside the supported policy set.  The returned name is stable
    and hashable — it is what goes into the op-cache key."""
    if compute_dtype is None or compute_dtype == "auto":
        return None
    if isinstance(compute_dtype, str):
        name = _ALIASES.get(compute_dtype, compute_dtype)
    else:
        try:
            name = jnp.dtype(compute_dtype).name
        except TypeError as e:
            raise ValueError(f"bad compute_dtype {compute_dtype!r}") from e
    if name not in _SUPPORTED:
        raise ValueError(
            f"unsupported compute_dtype {compute_dtype!r}; expected one of "
            f"{_SUPPORTED} (or None/'auto' to follow the input dtype)")
    return name


def resolve(compute_dtype, in_dtype):
    """The dtype kernel tiles are cast to at the VMEM boundary."""
    name = normalize(compute_dtype)
    return jnp.dtype(in_dtype) if name is None else jnp.dtype(name)


def cast_in(x, compute_dtype):
    """Cast a kernel input (the dominant HBM stream) to the compute dtype at
    the ``pallas_call`` boundary.  No-op on the f32 path."""
    dt = jnp.dtype(compute_dtype)
    return x if x.dtype == dt else x.astype(dt)


def cast_like(w, tile):
    """Cast on-the-fly f32 footprint weights to the streamed tile's dtype so
    the MXU contraction runs operand-matched (bf16 x bf16 with
    ``preferred_element_type=f32`` accumulation).  No-op on the f32 path."""
    return w.astype(tile.dtype)


def store_tile(out_ref, idx, acc):
    """Accumulate a float32 tile into the output ref *in the ref's dtype* —
    the single output-dtype policy point shared by all kernel pairs."""
    out_ref[idx] += acc.astype(out_ref.dtype)

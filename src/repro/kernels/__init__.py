"""Pallas TPU kernels + pure-jnp oracles for the differentiable projectors.

Importing this package registers every available Pallas kernel with the
dispatch table in ``repro.kernels.ops``.
"""
from repro.kernels import ops, ref, tune  # noqa: F401
from repro.kernels.tune import KernelConfig  # noqa: F401


def _register_all():
    from repro.kernels import fp_par
    fp_par.register()
    try:
        from repro.kernels import fp_cone
        fp_cone.register()
    except ImportError:
        pass
    try:
        from repro.kernels import fp_fan
        fp_fan.register()
    except ImportError:
        pass
    try:
        from repro.kernels import fp_modular
        fp_modular.register()
    except ImportError:
        pass


_register_all()

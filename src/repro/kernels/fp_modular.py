"""Pallas TPU kernels: modular-beam Separable-Footprint forward/back
projection — LEAP's distinguishing geometry class, on-kernel.

A modular geometry carries an arbitrary *per-view frame*: source position
``s_a``, detector center ``c_a``, and detector axes ``(e_u, e_v)``.  The
Pallas pair supports the **axial-frame** subclass — detector rows parallel
to the rotation axis (``e_v = ±ẑ``, ``e_u`` transaxial) with a free source
position *including z* — which covers the trajectories that fixed-geometry
kernels structurally cannot express: helical scans, per-view detector
shifts, non-uniform angular sampling, non-circular orbits.  Fully tilted
frames fall back to the Joseph ray-marching reference
(``ref.fp_modular_joseph``); ``modular_frames_axial`` is the dispatch gate.

The kernels are the exact cone pair (``fp_cone.py``) generalized to
per-view frames, and reduce to it exactly on axial circular trajectories
(``tests/test_modular.py`` pins this through ``cone_as_modular``):

* **Transaxial**: a per-view rescale + shear at trace time maps the modular
  corner projection onto the cone form with one *static* reference distance.
  With ``n̂`` the in-plane unit normal toward the detector,
  ``q = (p − s)·e_u``, ``ℓ = (p − s)·n̂``, ``sdd_a = (c − s)·n̂`` and the
  in-plane detector offset ``cu = (s − c)·e_u``, the detector coordinate of
  a corner is::

      u = sdd_a·(q + dq)/(ℓ + dl) + cu
        = SDD_REF·(q̂ + dq̂)/(ℓ + dl),   q̂ = (sdd_a/SDD_REF)·q + (cu/SDD_REF)·ℓ

  so the shared ``fp_cone._corner_trapezoid`` (and the window-start
  inversion) applies verbatim — only the per-view affine coefficients
  change.  The scalar-prefetched parameter row grows from 20 to 24 floats
  to carry the per-view axial frame (signed magnification numerator
  ``e_vz·sdd_a``, source height ``s_z``, row offset ``cv``).
* **Axial**: the per-element resample maps the volume z-line onto detector
  rows at ``v = (z − s_z)·(e_vz·sdd_a)/ℓ + cv`` — the cone kernel's
  per-element rect-overlap matvec with a per-view shift/offset (and a sign,
  handled by sorting the projected voxel edges).  This per-lane dependence
  is exactly why the modular pair uses the cone kernels' grid-folded
  batching, not fan-style lane packing (docs/KERNELS.md).
* **Batching**: a leading batch dim folds into the *view* grid axis (FP) /
  the *gathered-output* grid axis (BP), sharing one SMEM parameter table
  across samples — identical to the exact cone pair.

``bp_modular_sf_pallas`` is the exact transpose of the forward kernel
(same 24-float parameter rows, same corner-projected breakpoints,
transposed contraction + adjoint-direction axial matvec), so the
registered pair is *matched* and helical training/recon steps stay
on-kernel end to end.  ``fp_modular_sf_ref``/``bp_modular_sf_ref`` are the
jnp oracles (same frame math, no Pallas), and ``bp_modular_joseph_ref``
adjoins the Joseph reference for tilted frames.

Tile sizes come from :mod:`repro.kernels.tune` (``"modular"`` shape class).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.geometry import CTGeometry
from repro.kernels import precision, ref, tune
from repro.kernels.footprint import trapezoid_pixel_weight
from repro.kernels.fp_cone import _corner_trapezoid, _interpret, _round_up

_EPS = 1e-9
_AXIAL_TOL = 1e-4


# --------------------------------------------------------------------------- #
# Per-view frames
# --------------------------------------------------------------------------- #
def _frames(geom: CTGeometry):
    """Decompose the per-view modular frames into the kernel's quantities.

    Returns a dict of (na,)-shaped float64 arrays: source ``s``/``sz``,
    in-plane detector axis ``eu``, in-plane unit normal ``n`` oriented
    source -> detector, detector distance ``sdd`` along ``n``, in-plane /
    axial detector offsets ``cu``/``cv``, and the e_v z-sign ``evz``."""
    if geom.geom_type != "modular":
        raise ValueError(f"_frames needs a modular geometry, got "
                         f"geom_type={geom.geom_type!r}")
    s = np.asarray(geom.source_pos, np.float64)
    c = np.asarray(geom.det_center, np.float64)
    eu = np.asarray(geom.det_u, np.float64)
    ev = np.asarray(geom.det_v, np.float64)
    n = np.stack([eu[:, 1] * ev[:, 2], -eu[:, 0] * ev[:, 2],
                  np.zeros(len(eu))], -1)              # eu x ev (axial frames)
    d = c - s
    sdd = np.einsum("ai,ai->a", d, n)
    flip = np.sign(sdd)
    flip[flip == 0] = 1.0
    n = n * flip[:, None]
    sdd = sdd * flip
    return {
        "s": s, "sz": s[:, 2], "eu": eu, "ev": ev, "n": n, "sdd": sdd,
        "cu": -np.einsum("ai,ai->a", d, eu),
        "cv": -np.einsum("ai,ai->a", d, ev),
        "evz": ev[:, 2],
    }


def modular_frames_axial(geom: CTGeometry, fr=None) -> bool:
    """True when the per-view frames are in the axial subclass the SF pair
    supports: unit detector axes, ``e_u`` transaxial, ``e_v = ±ẑ``, a
    non-degenerate detector distance, and the source transaxially outside
    the volume for every view (the SF validity condition, the modular
    analogue of cone's ``sod > radius``).  ``fr`` accepts a precomputed
    ``_frames(geom)`` so entry points decompose the frames only once."""
    if geom.geom_type != "modular":
        return False
    eu = np.asarray(geom.det_u, np.float64)
    ev = np.asarray(geom.det_v, np.float64)
    if not (np.allclose(np.linalg.norm(eu, axis=1), 1.0, atol=_AXIAL_TOL)
            and np.allclose(np.linalg.norm(ev, axis=1), 1.0, atol=_AXIAL_TOL)
            and np.all(np.abs(eu[:, 2]) < _AXIAL_TOL)
            and np.all(np.abs(ev[:, 0]) < _AXIAL_TOL)
            and np.all(np.abs(ev[:, 1]) < _AXIAL_TOL)):
        return False
    fr = _frames(geom) if fr is None else fr
    if np.any(fr["sdd"] <= _AXIAL_TOL):
        return False
    lc, _ = _ell_center(geom, fr)
    return bool(np.all(lc - geom.vol.radius > 1e-3))


def _require_axial(geom: CTGeometry, fr=None):
    if not modular_frames_axial(geom, fr):
        raise NotImplementedError(
            "the modular SF pair supports axial frames (detector rows "
            "parallel to the rotation axis, source outside the volume); "
            "use model='joseph' (ray marching) for tilted frames")


def _ell_center(geom: CTGeometry, fr) -> Tuple[np.ndarray, float]:
    """Per-view in-plane distance from the source to the volume center along
    the detector normal, plus the volume's transaxial radius."""
    v = geom.vol
    p0 = np.asarray([v.offset_x, v.offset_y])
    lc = np.einsum("ai,ai->a", p0[None, :] - fr["s"][:, :2], fr["n"][:, :2])
    return lc, v.radius


def _mag_bounds_modular(geom: CTGeometry, fr) -> Tuple[float, float]:
    """(mag_min, mag_max) of the unsigned magnification sdd_a/ℓ over all
    views and the volume disk (the modular analogue of cone _mag_bounds)."""
    lc, r = _ell_center(geom, fr)
    mag_min = float(np.min(fr["sdd"] / (lc + r)))
    mag_max = float(np.max(fr["sdd"] / np.maximum(lc - r, 1e-3)))
    return mag_min, mag_max


# --------------------------------------------------------------------------- #
# Per-view affine parameters (24 floats)
# --------------------------------------------------------------------------- #
def _view_params_modular(geom: CTGeometry, fr=None
                         ) -> Tuple[np.ndarray, np.ndarray,
                                    np.ndarray, float]:
    """Per-view affine coefficients of q̂(gi, li) and ℓ(gi, li), the rx/ry
    affines, the four corner offsets (dq̂_k, dl_k), and the per-view axial
    frame, split into x-gathered (|n_y| >= |n_x|) and y-gathered groups.

    Layout per view (24 floats; [0:20] is the cone layout evaluated on the
    rescaled/sheared q̂ so ``_corner_trapezoid`` applies with the static
    ``sdd_ref`` returned alongside):

      [Aq, Bq, Cq, Al, Bl, Cl, Arx, Brx, Crx, Ary, Bry, Cry,
       dq0, dl0, dq1, dl1, dq2, dl2, dq3, dl3,
       mags (= e_vz * sdd_a), sz, cv, 0]
    """
    v = geom.vol
    fr = _frames(geom) if fr is None else fr
    x0, y0 = float(v.x_coords()[0]), float(v.y_coords()[0])
    hx, hy = v.dx / 2.0, v.dy / 2.0
    sdd_ref = float(np.median(fr["sdd"]))
    scale = fr["sdd"] / sdd_ref
    shear = fr["cu"] / sdd_ref
    eux, euy = fr["eu"][:, 0], fr["eu"][:, 1]
    nx, ny = fr["n"][:, 0], fr["n"][:, 1]
    sx, sy = fr["s"][:, 0], fr["s"][:, 1]
    # q̂ / ℓ direction cosines along world x/y (per view)
    qx = scale * eux + shear * nx
    qy = scale * euy + shear * ny
    C_off = (x0 - sx, y0 - sy)                        # volume corner - source
    Cq = qx * C_off[0] + qy * C_off[1]
    Cl = nx * C_off[0] + ny * C_off[1]

    def grp(gathered_x: bool):
        if gathered_x:                                # gi -> x, li -> y
            Aq, Bq = qx * v.dx, qy * v.dy
            Al, Bl = nx * v.dx, ny * v.dy
            Arx, Brx = v.dx * np.ones_like(nx), np.zeros_like(nx)
            Ary, Bry = np.zeros_like(nx), v.dy * np.ones_like(nx)
        else:                                         # gi -> y, li -> x
            Aq, Bq = qy * v.dy, qx * v.dx
            Al, Bl = ny * v.dy, nx * v.dx
            Arx, Brx = np.zeros_like(nx), v.dx * np.ones_like(nx)
            Ary, Bry = v.dy * np.ones_like(nx), np.zeros_like(nx)
        cols = [Aq, Bq, Cq, Al, Bl, Cl, Arx, Brx, C_off[0],
                Ary, Bry, C_off[1]]
        for ox in (-hx, hx):
            for oy in (-hy, hy):
                cols.append(qx * ox + qy * oy)        # dq̂
                cols.append(nx * ox + ny * oy)        # dl
        cols += [fr["evz"] * fr["sdd"], fr["sz"], fr["cv"],
                 np.zeros_like(nx)]
        return np.stack(cols, -1).astype(np.float32)

    gx = np.abs(ny) >= np.abs(nx)
    px, py = grp(True), grp(False)
    idx_x = np.nonzero(gx)[0]
    idx_y = np.nonzero(~gx)[0]
    return px[idx_x], py[idx_y], np.concatenate([idx_x, idx_y]), sdd_ref


# --------------------------------------------------------------------------- #
# Forward kernel
# --------------------------------------------------------------------------- #
def _fp_modular_kernel(params_ref,     # SMEM (n_views, 24)
                       f_ref,          # VMEM (NG, 1, NZ) volume line
                       out_ref,        # VMEM (1, BU, BV) sino tile
                       *, W: int, NZW: int, u0: float, du: float,
                       v0: float, dv: float, z0c: float, dz: float,
                       sdd_ref: float, dxv: float, ng: int, nz: int,
                       bu: int, bv: int, nav: int):
    """One program: one view x one (bu, bv) sino tile x one volume line —
    the exact cone FP kernel with the per-view frame read from the prefetch
    row: static ``sdd`` becomes ``sdd_ref`` (transaxial, via the q̂
    rescale) and the axial resample picks up the per-view signed
    magnification, source height, and row offset."""
    a = pl.program_id(0)
    ub = pl.program_id(1)
    vb = pl.program_id(2)
    li = pl.program_id(3)

    @pl.when(li == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    av = jax.lax.rem(a, nav)                 # batch folded into the view axis
    P = [params_ref[av, i] for i in range(24)]
    Aq, Bq, Cq, Al, Bl, Cl = P[:6]
    mags, sz, cv = P[20], P[21], P[22]
    lif = li.astype(jnp.float32)
    u_first = u0 + (ub * bu) * du
    u_last = u_first + (bu - 1) * du

    # window start: invert u = sdd_ref*(Aq*gi + q0)/(Al*gi + l0)
    q0 = Bq * lif + Cq
    l0 = Bl * lif + Cl

    def gi_of(u):
        den = sdd_ref * Aq - u * Al
        den = jnp.where(jnp.abs(den) > 1e-6,
                        den, jnp.where(den >= 0, 1e-6, -1e-6))
        return (u * l0 - sdd_ref * q0) / den

    g1, g2 = gi_of(u_first), gi_of(u_last)
    start = jnp.floor(jnp.minimum(g1, g2)).astype(jnp.int32) - (
        W - jnp.abs(jnp.ceil(g2 - g1)).astype(jnp.int32)) // 2
    start = jnp.clip(start, 0, max(ng - W, 0))

    gi = start.astype(jnp.float32) + jax.lax.broadcasted_iota(
        jnp.float32, (1, W), 1)                              # (1, W)
    t0, t1, t2, t3, h, rt2 = _corner_trapezoid(P, gi, q0, l0, lif,
                                               sdd_ref, dxv)

    uk = u_first + du * jax.lax.broadcasted_iota(jnp.float32, (bu, 1), 0)
    el = uk - du / 2.0
    wu = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)  # (bu, W)

    ell = jnp.maximum(Al * gi + l0, _EPS)
    mag = mags / ell                         # signed per-element magnification
    v_first = v0 + (vb * bv) * dv
    v_last = v_first + (bv - 1) * dv
    vlane = v_first + dv * jax.lax.broadcasted_iota(jnp.float32, (bv, 1), 0)

    acc = jnp.zeros((bu, bv), jnp.float32)
    for w in range(W):
        mag_w = mag[0, w]
        rt2_w = rt2[0, w]
        inv_mag = ell[0, w] / mags           # sign-safe 1/mag (|mags| > 0)
        # z window covering this row block at this view's axial map
        zc_a = (v_first - cv) * inv_mag + sz
        zc_b = (v_last - cv) * inv_mag + sz
        z0i = jnp.floor((jnp.minimum(zc_a, zc_b) - z0c) / dz
                        ).astype(jnp.int32) - 2
        z0i = jnp.clip(z0i, 0, max(nz - NZW, 0))
        zt = z0c + (z0i.astype(jnp.float32)
                    + jax.lax.broadcasted_iota(jnp.float32, (1, NZW), 1)) * dz
        va = (zt - dz / 2.0 - sz) * mag_w + cv           # (1, NZW)
        vb_ = (zt + dz / 2.0 - sz) * mag_w + cv
        vlo = jnp.minimum(va, vb_)           # sorted: mag may be negative
        vhi = jnp.maximum(va, vb_)
        elv = vlane - dv / 2.0                               # (bv, 1)
        ov = jnp.maximum(jnp.minimum(vhi, elv + dv)
                         - jnp.maximum(vlo, elv), 0.0) / dv  # (bv, NZW)
        obl = jnp.sqrt(1.0 + ((zt - sz) * (zt - sz))
                       / jnp.maximum(rt2_w, 1e-9))
        Wz = ov * obl                                        # (bv, NZW)
        fwin = f_ref[start + w, 0, pl.ds(z0i, NZW)]          # (NZW,)
        rv = jax.lax.dot_general(precision.cast_like(Wz, fwin), fwin[:, None],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)[:, 0]
        acc = acc + wu[:, w][:, None] * rv[None, :]
    precision.store_tile(out_ref, 0, acc)


def _fp_window_sizes(geom: CTGeometry, bu: int, bv: int, ng: int, nz: int,
                     mag_min: float, mag_max: float) -> Tuple[int, int]:
    vol = geom.vol
    du, dv = geom.pixel_width, geom.pixel_height
    span = bu * du * math.sqrt(2.0) / (vol.dx * mag_min)
    margin = 2.0 * (math.sqrt(2.0) * vol.dx * mag_max + du) \
        / (vol.dx * mag_min) + 4.0
    W = min(int(math.ceil(span + 2 * margin)) + 2, ng)
    NZW = min(int(math.ceil(bv * dv / (mag_min * vol.dz))) + 6, nz)
    return W, NZW


def _run_fp_group(fb, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bu: int, bv: int, sdd_ref: float,
                  mag_min: float, mag_max: float):
    """fb: (B, nx, ny, nz) batch of volumes; the batch is folded into the
    view grid axis exactly like the exact cone FP.  Returns
    (B, na_group, NUp, NVp)."""
    if params.shape[0] == 0:
        return None
    vol = geom.vol
    if not gathered_x:
        fb = jnp.swapaxes(fb, 1, 2)
    B, ng, nl, nz = fb.shape
    fs = fb.reshape(B * ng, nl, nz)
    na = params.shape[0]
    nup = _round_up(geom.n_cols, bu)
    nvp = _round_up(geom.n_rows, bv)
    W, NZW = _fp_window_sizes(geom, bu, bv, ng, nz, mag_min, mag_max)
    kernel = functools.partial(
        _fp_modular_kernel, W=W, NZW=NZW,
        u0=float(geom.u_coords()[0]), du=geom.pixel_width,
        v0=float(geom.v_coords()[0]), dv=geom.pixel_height,
        z0c=float(vol.z_coords()[0]), dz=vol.dz,
        sdd_ref=sdd_ref, dxv=vol.dx, ng=ng, nz=nz, bu=bu, bv=bv, nav=na)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * na, nup // bu, nvp // bv, nl),
            in_specs=[pl.BlockSpec((ng, 1, nz),
                                   lambda a, ub, vb, l, *_: (a // na, l, 0))],
            out_specs=pl.BlockSpec((1, bu, bv),
                                   lambda a, ub, vb, l, *_: (a, ub, vb)),
        ),
        # output buffer is the cross-step accumulator: always f32
        out_shape=jax.ShapeDtypeStruct((B * na, nup, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), fs)
    return out.reshape(B, na, nup, nvp)


def fp_modular_sf_pallas(f, geom: CTGeometry, bu: Optional[int] = None,
                         bv: Optional[int] = None,
                         config: Optional[tune.KernelConfig] = None,
                         compute_dtype=None):
    """f: (nx, ny, nz) -> sino (n_angles, n_rows, n_cols), or batched
    f: (batch, nx, ny, nz) -> (batch, ...).  Axial modular frames."""
    if geom.geom_type != "modular":
        raise ValueError(f"fp_modular_sf_pallas needs a modular geometry, "
                         f"got geom_type={geom.geom_type!r}; dispatch "
                         f"through get_ops/forward_project for auto kernel "
                         f"selection")
    fr = _frames(geom)
    _require_axial(geom, fr)
    if f.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D volume, got {f.shape}")
    batched = f.ndim == 4
    out_dtype = f.dtype
    cdt = precision.resolve(compute_dtype, f.dtype)
    fb = precision.cast_in(f if batched else f[None], cdt)
    cfg = tune.resolve_config(geom, fb.shape[0], config, dtype=cdt,
                              bu=bu, bv=bv)
    px, py, order, sdd_ref = _view_params_modular(geom, fr)
    mag_min, mag_max = _mag_bounds_modular(geom, fr)
    outs = []
    o1 = _run_fp_group(fb, px, geom, True, cfg.bu, cfg.bv, sdd_ref,
                       mag_min, mag_max)
    if o1 is not None:
        outs.append(o1)
    o2 = _run_fp_group(fb, py, geom, False, cfg.bu, cfg.bv, sdd_ref,
                       mag_min, mag_max)
    if o2 is not None:
        outs.append(o2)
    out = jnp.concatenate(outs, axis=1)                # (B, na, NUp, NVp)
    out = out[:, :, :geom.n_cols, :geom.n_rows]
    inv = np.argsort(order)
    out = jnp.swapaxes(out[:, inv], 2, 3).astype(out_dtype)  # (B, na, nv, nu)
    return out if batched else out[0]


# --------------------------------------------------------------------------- #
# Backprojection kernel (exact transpose)
# --------------------------------------------------------------------------- #
def _bp_modular_kernel(params_ref,     # SMEM (n_views, 24)
                       q_ref,          # VMEM (bab, NU, bv) u-major sino stripes
                       out_ref,        # VMEM (bg, 1, nz) volume tile (z lanes)
                       *, Wu: int, u0: float, du: float, v0: float, dv: float,
                       z0c: float, dz: float, sdd_ref: float, dxv: float,
                       nu: int, nz: int, bg: int, bv: int, bab: int,
                       ngb: int):
    """Exact transpose of ``_fp_modular_kernel`` — the cone BP kernel with
    the per-view frame read from the 24-float prefetch row: the same
    corner-projected breakpoints contracted in the transposed direction,
    and each gathered element's (bv, nz) rect-overlap matrix (signed
    per-view magnification, source height, row offset) mapping its
    u-contracted detector rows back onto the volume's z lanes."""
    gall = pl.program_id(0)
    li = pl.program_id(1)
    vb = pl.program_id(2)
    ab = pl.program_id(3)

    @pl.when((vb == 0) & (ab == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    gi0 = jax.lax.rem(gall, ngb) * bg        # batch folded into gathered axis
    gi_abs = gi0.astype(jnp.float32) + jax.lax.broadcasted_iota(
        jnp.float32, (bg, 1), 0)                             # (bg, 1)
    v_first = v0 + (vb * bv) * dv
    elv = v_first - dv / 2.0 + dv * jax.lax.broadcasted_iota(
        jnp.float32, (bv, 1), 0)                             # (bv, 1)
    zt = z0c + dz * jax.lax.broadcasted_iota(jnp.float32, (1, nz), 1)

    acc = jnp.zeros((bg, nz), jnp.float32)
    for j in range(bab):
        a = ab * bab + j
        P = [params_ref[a, i] for i in range(24)]
        Aq, Bq, Cq, Al, Bl, Cl = P[:6]
        mags, sz, cv = P[20], P[21], P[22]
        q0 = Bq * lif + Cq
        l0 = Bl * lif + Cl

        # window start: center projection u(gi) over the gathered tile
        def uc_of(gi):
            qg = Aq * gi + q0
            lg = jnp.maximum(Al * gi + l0, _EPS)
            return sdd_ref * qg / lg

        uc_a = uc_of(gi0.astype(jnp.float32))
        uc_b = uc_of((gi0 + bg - 1).astype(jnp.float32))
        ustart = jnp.floor(
            (jnp.minimum(uc_a, uc_b) - u0) / du).astype(jnp.int32) - (
            Wu - jnp.abs(jnp.ceil((uc_b - uc_a) / du)).astype(jnp.int32)) // 2
        ustart = jnp.clip(ustart, 0, max(nu - Wu, 0))

        qwin = q_ref[j, pl.ds(ustart, Wu), :]                # (Wu, bv)
        t0, t1, t2, t3, h, rt2 = _corner_trapezoid(
            P, gi_abs, q0, l0, lif, sdd_ref, dxv)            # (bg, 1)
        uk = u0 + (ustart.astype(jnp.float32)
                   + jax.lax.broadcasted_iota(jnp.float32, (1, Wu), 1)) * du
        el = uk - du / 2.0                                   # (1, Wu)
        wgt = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
        rows = jax.lax.dot_general(precision.cast_like(wgt, qwin),
                                   qwin,                     # (bg, bv)
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        zcols = []
        for g in range(bg):
            ell_g = jnp.maximum(Al * gi_abs[g, 0] + l0, _EPS)
            mag_g = mags / ell_g
            va = (zt - dz / 2.0 - sz) * mag_g + cv           # (1, nz)
            vb_ = (zt + dz / 2.0 - sz) * mag_g + cv
            vlo = jnp.minimum(va, vb_)
            vhi = jnp.maximum(va, vb_)
            ov = jnp.maximum(jnp.minimum(vhi, elv + dv)
                             - jnp.maximum(vlo, elv), 0.0) / dv   # (bv, nz)
            obl = jnp.sqrt(1.0 + ((zt - sz) * (zt - sz))
                           / jnp.maximum(rt2[g, 0], _EPS))
            Wz = ov * obl                                    # (bv, nz)
            zcols.append(jax.lax.dot_general(
                rows[g][None, :], Wz, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))         # (1, nz)
        acc = acc + jnp.concatenate(zcols, axis=0)
    precision.store_tile(out_ref, (slice(None), 0, slice(None)), acc)


def _u_window_size_modular(geom: CTGeometry, bg: int, nu: int,
                           mag_max: float) -> int:
    du, dx = geom.pixel_width, geom.vol.dx
    span = bg * dx * math.sqrt(2.0) * mag_max / du
    margin = 2.0 * math.sqrt(2.0) * dx * mag_max / du + 4.0
    w = int(math.ceil(span + 2 * margin)) + 2
    return min(_round_up(max(w, 8), 8), nu)


def _run_bp_group(q, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bg: int, bv: int, bab: int, sdd_ref: float, mag_max: float):
    """q: (B, na_group, n_cols, n_rows) u-major sino slice.  Batch folded
    into the gathered-output grid axis (the transpose of the FP's view-axis
    folding).  Returns (B, NG, NL, nz)."""
    vol = geom.vol
    ng, nl = (vol.nx, vol.ny) if gathered_x else (vol.ny, vol.nx)
    nz = vol.nz
    B, na, nu_, nv_ = q.shape
    bab = max(1, min(bab, na))
    nap = _round_up(na, bab)
    if nap != na:
        params = np.concatenate([params, np.repeat(params[-1:],
                                                   nap - na, 0)], 0)
        q = jnp.pad(q, ((0, 0), (0, nap - na), (0, 0), (0, 0)))
    nvp = _round_up(nv_, bv)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, nvp - nv_)))
    qs = q.reshape(B * nap, nu_, nvp)
    ngp = _round_up(ng, bg)
    ngb, nab = ngp // bg, nap // bab
    Wu = _u_window_size_modular(geom, bg, nu_, mag_max)
    kernel = functools.partial(
        _bp_modular_kernel, Wu=Wu,
        u0=float(geom.u_coords()[0]), du=geom.pixel_width,
        v0=float(geom.v_coords()[0]), dv=geom.pixel_height,
        z0c=float(vol.z_coords()[0]), dz=vol.dz, sdd_ref=sdd_ref,
        dxv=vol.dx, nu=nu_, nz=nz, bg=bg, bv=bv, bab=bab, ngb=ngb)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * ngb, nl, nvp // bv, nab),
            in_specs=[pl.BlockSpec((bab, nu_, bv),
                                   lambda gall, l, vb, ab, *_:
                                   (gall // ngb * nab + ab, 0, vb))],
            out_specs=pl.BlockSpec((bg, 1, nz),
                                   lambda gall, l, vb, ab, *_: (gall, l, 0)),
        ),
        # output buffer is the cross-step accumulator: always f32
        out_shape=jax.ShapeDtypeStruct((B * ngp, nl, nz), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), qs)
    return out.reshape(B, ngp, nl, nz)[:, :ng]


def bp_modular_sf_pallas(sino, geom: CTGeometry, bg: Optional[int] = None,
                         bv: Optional[int] = None, bab: Optional[int] = None,
                         config: Optional[tune.KernelConfig] = None,
                         compute_dtype=None):
    """sino: (n_angles, n_rows, n_cols) -> volume (nx, ny, nz), or batched
    sino: (batch, ...) -> (batch, nx, ny, nz).  Exact transpose of
    ``fp_modular_sf_pallas`` (incl. the batched path)."""
    if geom.geom_type != "modular":
        raise ValueError(f"bp_modular_sf_pallas needs a modular geometry, "
                         f"got geom_type={geom.geom_type!r}; dispatch "
                         f"through get_ops/back_project for auto kernel "
                         f"selection")
    fr = _frames(geom)
    _require_axial(geom, fr)
    if sino.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D sinogram, got {sino.shape}")
    batched = sino.ndim == 4
    out_dtype = sino.dtype
    cdt = precision.resolve(compute_dtype, sino.dtype)
    qb = sino if batched else sino[None]
    cfg = tune.resolve_config(geom, qb.shape[0], config, dtype=cdt,
                              bg=bg, bv=bv, bab=bab)
    px, py, order, sdd_ref = _view_params_modular(geom, fr)
    _, mag_max = _mag_bounds_modular(geom, fr)
    q = jnp.swapaxes(qb, 2, 3)                         # (B, na, nu, nv)
    q = precision.cast_in(q[:, order], cdt)            # group-major views
    nax = px.shape[0]
    acc = jnp.zeros((qb.shape[0],) + geom.vol.shape, jnp.float32)
    if nax:
        acc = acc + _run_bp_group(q[:, :nax], px, geom, True,
                                  cfg.bg, cfg.bv, cfg.bab, sdd_ref, mag_max)
    if py.shape[0]:
        accy = _run_bp_group(q[:, nax:], py, geom, False,
                             cfg.bg, cfg.bv, cfg.bab, sdd_ref, mag_max)
        acc = acc + jnp.swapaxes(accy, 1, 2)
    acc = acc.astype(out_dtype)
    return acc if batched else acc[0]


# --------------------------------------------------------------------------- #
# jnp oracles
# --------------------------------------------------------------------------- #
def fp_modular_sf_ref(f, geom: CTGeometry):
    """Separable-footprint modular forward projection in pure jnp — the
    oracle for the Pallas pair (same frame math, no windowing), and the
    ``model="sf"`` modular entry of the ``ref`` backend.  Tilted
    (non-axial) frames delegate to the Joseph ray-marching reference, the
    same fallback the seed applied to all modular geometries.

    Like the other oracles this scans over views (per-view frame scalars
    ride the scan carry), so trace/compile cost is independent of the view
    count — helical recon on the ref backend stays usable."""
    fr = _frames(geom)
    if not modular_frames_axial(geom, fr):
        return ref.fp_modular_joseph(f, geom)
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    du, dv = geom.pixel_width, geom.pixel_height
    _, mag_max = _mag_bounds_modular(geom, fr)
    Ku = int(math.ceil(math.sqrt(2.0) * v.dx * mag_max / du)) + 2
    Kv = int(math.ceil(v.dz * mag_max / dv)) + 2
    uedge0 = float(geom.u_coords()[0]) - du / 2.0
    vedge0 = float(geom.v_coords()[0]) - dv / 2.0
    X = jnp.asarray(np.repeat(v.x_coords(), ny))             # (nxy,)
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    Z = jnp.asarray(v.z_coords())                            # (nz,)
    hx, hy = v.dx / 2.0, v.dy / 2.0
    fflat = f.reshape(nx * ny, nz)
    views = jnp.asarray(np.stack(
        [fr["s"][:, 0], fr["s"][:, 1], fr["sz"],
         fr["eu"][:, 0], fr["eu"][:, 1], fr["n"][:, 0], fr["n"][:, 1],
         fr["sdd"], fr["cu"], fr["cv"], fr["evz"] * fr["sdd"]],
        -1).astype(np.float32))                              # (na, 11)

    def one_view(_, vd):
        sx, sy, sz, eux, euy, nxh, nyh, sdd_a, cu, cv, mags = (
            vd[i] for i in range(11))
        rx, ry = X - sx, Y - sy
        q = rx * eux + ry * euy
        ell = rx * nxh + ry * nyh
        taus = []
        for ox in (-hx, hx):
            for oy in (-hy, hy):
                dq = ox * eux + oy * euy
                dl = ox * nxh + oy * nyh
                taus.append(sdd_a * (q + dq)
                            / jnp.maximum(ell + dl, _EPS) + cu)
        taus = jnp.sort(jnp.stack(taus, -1), -1)
        t0, t1, t2, t3 = (taus[..., 0], taus[..., 1], taus[..., 2],
                          taus[..., 3])
        rt2 = rx * rx + ry * ry
        h = v.dx * jnp.sqrt(rt2) / jnp.maximum(
            jnp.maximum(jnp.abs(rx), jnp.abs(ry)), _EPS)
        obl = jnp.sqrt(1.0 + ((Z[None, :] - sz) ** 2)
                       / jnp.maximum(rt2[:, None], _EPS))
        mag = mags / jnp.maximum(ell, _EPS)                  # signed, (nxy,)
        va = (Z[None, :] - v.dz / 2 - sz) * mag[:, None] + cv
        vb = (Z[None, :] + v.dz / 2 - sz) * mag[:, None] + cv
        vlo = jnp.minimum(va, vb)                            # (nxy, nz)
        vhi = jnp.maximum(va, vb)
        # Same 1e-4 floor nudge as the cone/fan oracles (bin-boundary ulp).
        ku0 = jnp.floor((t0 - uedge0) / du + 1e-4).astype(jnp.int32)
        kv0 = jnp.floor((vlo - vedge0) / dv + 1e-4).astype(jnp.int32)
        vals = fflat * obl                                   # (nxy, nz)
        acc = jnp.zeros((nv * nu,), f.dtype)
        for ku in range(Ku):
            iu = ku0 + ku
            el = uedge0 + iu.astype(f.dtype) * du
            wu = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
            oku = (iu >= 0) & (iu < nu)
            wu = jnp.where(oku, wu, 0.0)
            iuc = jnp.clip(iu, 0, nu - 1)                    # (nxy,)
            for kv in range(Kv):
                iv = kv0 + kv                                # (nxy, nz)
                elv = vedge0 + iv.astype(f.dtype) * dv
                wv = jnp.maximum(jnp.minimum(vhi, elv + dv)
                                 - jnp.maximum(vlo, elv), 0.0) / dv
                okv = (iv >= 0) & (iv < nv)
                wv = jnp.where(okv, wv, 0.0)
                ivc = jnp.clip(iv, 0, nv - 1)
                idx = ivc * nu + iuc[:, None]                # (nxy, nz)
                acc = acc + jax.ops.segment_sum(
                    (vals * wu[:, None] * wv).reshape(-1),
                    idx.reshape(-1), num_segments=nv * nu)
        return 0, acc.reshape(nv, nu)

    _, sino = jax.lax.scan(one_view, 0, views)
    return sino


def bp_modular_sf_ref(sino, geom: CTGeometry):
    """Exact linear transpose of the SF oracle (via jax.vjp) — the
    cross-check for ``bp_modular_sf_pallas``."""
    f0 = jnp.zeros(geom.vol.shape, sino.dtype)
    _, vjp = jax.vjp(lambda x: fp_modular_sf_ref(x, geom), f0)
    return vjp(sino)[0]


def bp_modular_joseph_ref(sino, geom: CTGeometry):
    """Adjoint of the Joseph ray-marching modular reference (via jax.vjp) —
    the oracle pair for tilted frames the SF kernels don't cover."""
    return ref.adjoint(sino, geom, "joseph")


def register():
    from repro.kernels import ops
    ops.register_kernel("modular", "sf",
                        fp_modular_sf_pallas, bp_modular_sf_pallas,
                        fp_batched=fp_modular_sf_pallas,
                        bp_batched=bp_modular_sf_pallas,
                        supports=modular_frames_axial)
    # The SF oracle doubles as the ref-backend modular "sf" model (the seed
    # silently downgraded every modular request to joseph).
    ref.register_reference("modular", "sf", fp_modular_sf_ref)

"""Pallas TPU flash attention (forward): online-softmax with *causal block
skipping*.

Why it exists (EXPERIMENTS.md §Roofline): the pure-jnp chunked attention in
``models/layers.py`` must compute fully-masked off-diagonal blocks (XLA
cannot skip them), wasting ~2x attention FLOPs on causal training/prefill.
A Pallas grid can: blocks with ``kv_block > q_block`` are skipped with
``pl.when`` — no MXU work is issued for them.

Supports GQA (grid dimension per kv-head x group) and sliding windows
(blocks outside the window are skipped too).  Forward-only: the training
path keeps the jnp chunked implementation (autodiff-able); this kernel is
the serving/prefill fast path and the reference for a future custom-vjp
backward.

ref.py oracle: ``flash_ref`` below (numerically the standard softmax).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_ref(q, k, v, window: Optional[int] = None):
    """Oracle: q (B,H,S,hd), k/v (B,KV,S,hd) -> (B,H,S,hd), causal."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd)
    s = jnp.einsum("bkgsh,bkth->bkgst", qg, k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    keep = kp <= qp
    if window is not None:
        keep &= kp > qp - window
    s = jnp.where(keep[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,bkth->bkgsh", w, v)
    return o.reshape(B, H, S, hd)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s,
                  *, bq: int, bk: int, nk: int, scale: float,
                  window: Optional[int], hd: int):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start = qi * bq
    k_start = ki * bk
    needed = k_start <= q_start + bq - 1                 # causal block skip
    if window is not None:
        needed &= (k_start + bk - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, 0]                                # (bq, hd)
        k = k_ref[0, 0]                                   # (bk, hd)
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = kp <= qp
        if window is not None:
            keep &= kp > qp - window
        s = jnp.where(keep, s, NEG_INF)
        m_prev = m_s[...]
        l_prev = l_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_prev * corr + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, window: Optional[int] = None,
                    bq: int = 512, bk: int = 512):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd).  Causal."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq = min(bq, S)
    bk = min(bk, S)
    if S % bq or S % bk:
        raise ValueError(
            f"flash_attention needs the sequence length to be divisible by "
            f"both block sizes, got S={S} with bq={bq}, bk={bk}; pad the "
            f"sequence or pass block sizes that divide it")
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, S, hd)

    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, window=window, hd=hd)
    grid = (B, KV, G, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd),
                         lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=_interpret(),
    )(qg, k, v)
    return out.reshape(B, H, S, hd)


# --------------------------------------------------------------------------- #
# Backward (FlashAttention-2 style): two block-skipping kernels + custom_vjp
# --------------------------------------------------------------------------- #
def _flash_fwd_stats_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc, m_s, l_s,
                            *, bq, bk, nk, scale, window):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)

    q_start, k_start = qi * bq, ki * bk
    needed = k_start <= q_start + bq - 1
    if window is not None:
        needed &= (k_start + bk - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = kp <= qp
        if window is not None:
            keep &= kp > qp - window
        s = jnp.where(keep, s, NEG_INF)
        m_new = jnp.maximum(m_s[...], s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_s[...] - m_new)
        l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                          ).astype(o_ref.dtype)
        lse_ref[0, 0, 0] = (m_s[...] + jnp.log(
            jnp.maximum(l_s[...], 1e-30)))[:, 0]


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, acc, *, bq, bk, nk, scale, window):
    qi = pl.program_id(3)
    ki = pl.program_id(4)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q_start, k_start = qi * bq, ki * bk
    needed = k_start <= q_start + bq - 1
    if window is not None:
        needed &= (k_start + bk - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0, 0][:, None]
        delta = delta_ref[0, 0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = kp <= qp
        if window is not None:
            keep &= kp > qp - window
        p = jnp.where(keep, jnp.exp(s - lse), 0.0)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        acc[...] += jax.lax.dot_general(ds, k.astype(jnp.float32),
                                        (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _fin():
        dq_ref[0, 0, 0] = acc[...].astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, dk_acc, dv_acc,
                          *, bq, bk, nq, scale, window):
    ki = pl.program_id(3)
    qi = pl.program_id(4)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start, k_start = qi * bq, ki * bk
    needed = k_start <= q_start + bq - 1
    if window is not None:
        needed &= (k_start + bk - 1) > (q_start - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0, 0]
        k = k_ref[0, 0]
        v = v_ref[0, 0]
        do = do_ref[0, 0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0, 0][:, None]
        delta = delta_ref[0, 0, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        keep = kp <= qp
        if window is not None:
            keep &= kp > qp - window
        p = jnp.where(keep, jnp.exp(s - lse), 0.0)
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v.astype(jnp.float32),
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_acc[...] += jax.lax.dot_general(ds, q.astype(jnp.float32),
                                           (((0,), (0,)), ((), ())),
                                           preferred_element_type=jnp.float32)

    @pl.when(qi == nq - 1)
    def _fin():
        dk_ref[0, 0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _fwd_with_stats(q, k, v, window, bq, bk):
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, S, hd)
    kernel = functools.partial(_flash_fwd_stats_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, window=window)
    o, lse = pl.pallas_call(
        kernel,
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, kv, g, qi, ki: (b, kv, g, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, KV, G, S), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32)],
        interpret=_interpret(),
    )(qg, k, v)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_diff(q, k, v, window=None, bq=512, bk=512):
    """Differentiable flash attention (forward + FlashAttention-2 backward,
    both with causal block skipping).  Same signature as flash_attention."""
    B, H, S, hd = q.shape
    o, _ = _fwd_with_stats(q, k, v, window, min(bq, S), min(bk, S))
    return o.reshape(B, H, S, hd)


def _fa_fwd(q, k, v, window, bq, bk):
    B, H, S, hd = q.shape
    bq, bk = min(bq, S), min(bk, S)
    o, lse = _fwd_with_stats(q, k, v, window, bq, bk)
    return o.reshape(B, H, S, hd), (q, k, v, o, lse)


def _fa_bwd(window, bq, bk, res, do):
    q, k, v, o, lse = res
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    bq, bk = min(bq, S), min(bk, S)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, S, hd)
    dog = do.reshape(B, KV, G, S, hd)
    delta = jnp.sum(dog.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, bq=bq, bk=bk, nk=nk,
                          scale=scale, window=window),
        grid=(B, KV, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, qi, ki: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, kv, g, qi, ki: (b, kv, g, qi)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, kv, g, qi, ki: (b, kv, g, qi)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, bq, hd),
                               lambda b, kv, g, qi, ki: (b, kv, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=_interpret(),
    )(qg, k, v, dog, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, bq=bq, bk=bk, nq=nq,
                          scale=scale, window=window),
        grid=(B, KV, G, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, ki, qi: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, ki, qi: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, kv, g, ki, qi: (b, kv, ki, 0)),
            pl.BlockSpec((1, 1, 1, bq, hd), lambda b, kv, g, ki, qi: (b, kv, g, qi, 0)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, kv, g, ki, qi: (b, kv, g, qi)),
            pl.BlockSpec((1, 1, 1, bq), lambda b, kv, g, ki, qi: (b, kv, g, qi)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, bk, hd), lambda b, kv, g, ki, qi: (b, kv, g, ki, 0)),
            pl.BlockSpec((1, 1, 1, bk, hd), lambda b, kv, g, ki, qi: (b, kv, g, ki, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, KV, G, S, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, KV, G, S, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=_interpret(),
    )(qg, k, v, dog, lse, delta)
    # per-group dk/dv sum over the G query heads sharing each kv head
    dq = dq.reshape(B, H, S, hd)
    dk = dk.sum(axis=2)
    dv = dv.sum(axis=2)
    return dq, dk, dv


flash_attention_diff.defvjp(_fa_fwd, _fa_bwd)

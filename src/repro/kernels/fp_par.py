"""Pallas TPU kernels: parallel-beam Separable-Footprint forward/back projection.

TPU-native design (see DESIGN.md §2).  LEAP's CUDA kernels are
thread-per-output with 3D texture gathers; here each Pallas program computes a
``(bu detector columns) x (bv lanes)`` output tile for a block of ``ba`` views
by looping over the volume's *loop axis* and, per step, contracting a
``(bu, W)`` footprint-weight tile against a ``(W, bv)`` volume window on the
MXU.  The footprint weights are exact SF trapezoid-pixel integrals; the
``W``-wide window along the *gathered axis* is addressed with a scalar
``pl.dynamic_slice`` start computed from per-view affine coefficients held in
SMEM (scalar prefetch) — no gather hardware required.

Views are partitioned at trace time (geometry is static) into an
``x-gathered`` group (|sin| >= |cos|) and a ``y-gathered`` group, which run as
two ``pallas_call``s over the volume and its transpose; this replaces the
per-ray driving-axis branch of GPU implementations.

The axial (z -> detector row) part of the separable footprint is an
angle-independent banded matrix for parallel beams and is applied as a single
einsum outside the kernel (it maps to the MXU directly).

**Lane packing.**  Because the axial part is hoisted out, the kernel's lane
axis is purely data-parallel: every lane sees the same footprint weights and
the same gathered-axis window.  Batched inputs therefore fold the batch
dimension *into the lanes* — ``batch x n_rows`` detector rows are packed onto
the 128-wide axis — instead of vmapping the ``pallas_call`` per sample.  For
the paper's flagship 2D limited-angle training shape (nz=1, n_rows=1) this
turns ~1/128 lane occupancy into full tiles: up to 128x more useful MXU work
per contraction.  Both public entry points accept a leading batch dim.

Tile/block sizes come from :mod:`repro.kernels.tune` (``KernelConfig``);
the old hard-coded ``BU``/``BV`` module constants are gone.

Both kernels share the weight math; the backprojector is the exact transpose
of the forward (same coefficients, transposed contraction), so the pair is
*matched* in the paper's sense.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.geometry import CTGeometry
from repro.kernels import precision, tune
from repro.kernels.footprint import trapezoid_pixel_weight
from repro.kernels.ref import _z_overlap_matrix


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# --------------------------------------------------------------------------- #
# Per-view affine coefficients (static, numpy)
# --------------------------------------------------------------------------- #
def _view_params(geom: CTGeometry) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Split views into x-gathered / y-gathered groups and compute, per view,
    the coefficients of  uc(gi, li) = P*gi + Q*li + R  (detector coordinate of
    the voxel center at gathered-index gi, loop-index li) plus the SF
    trapezoid parameters (hs, hd, h)."""
    v = geom.vol
    ang = geom.angles_array()
    c, s = np.cos(ang), np.sin(ang)
    x0, y0 = float(v.x_coords()[0]), float(v.y_coords()[0])
    a = v.dx * np.abs(c)
    b = v.dx * np.abs(s)
    hs = 0.5 * (a + b)
    hd = 0.5 * np.abs(a - b)
    h = v.dx / np.maximum(np.abs(c), np.abs(s))
    gx = np.abs(s) >= np.abs(c)          # x-gathered group
    # x-gathered: gi = ix, li = iy:  uc = -s*dx*gi + c*dy*li + (c*y0 - s*x0)
    px = np.stack([-s * v.dx, c * v.dy, c * y0 - s * x0, hs, hd, h], -1)
    # y-gathered: gi = iy, li = ix:  uc =  c*dy*gi - s*dx*li + (c*y0 - s*x0)
    py = np.stack([c * v.dy, -s * v.dx, c * y0 - s * x0, hs, hd, h], -1)
    idx_x = np.nonzero(gx)[0]
    idx_y = np.nonzero(~gx)[0]
    return (px[idx_x].astype(np.float32), py[idx_y].astype(np.float32),
            np.concatenate([idx_x, idx_y]))


def _window_size(geom: CTGeometry, bu: int) -> int:
    """Static bound on the gathered-axis window covering one u-tile.
    |duc/dgi| >= dx/sqrt(2) in-group, so the tile spans <= bu*du*sqrt(2)/dx
    voxels, plus the footprint half-width margin on each side."""
    du, dx = geom.pixel_width, geom.vol.dx
    span = bu * du * math.sqrt(2.0) / dx
    margin = 2.0 * (math.sqrt(2.0) / 2.0 * dx + du) / dx + 2.0
    w = int(math.ceil(span + 2 * margin)) + 2
    return _round_up(max(w, 8), 8)


def _pad_views(params: np.ndarray, block: int, q=None):
    """Pad a view group to a multiple of ``block`` views.  Params rows are
    duplicated (keeps the weight math finite); the optional sinogram data
    ``q`` is zero-padded so padded views contribute nothing.  Returns
    (params, q, clipped_block)."""
    na = params.shape[0]
    block = max(1, min(block, na))
    nap = ((na + block - 1) // block) * block
    if nap != na:
        params = np.concatenate([params, np.repeat(params[-1:],
                                                   nap - na, 0)], 0)
        if q is not None:
            q = jnp.pad(q, ((0, nap - na), (0, 0), (0, 0)))
    return params, q, block


# --------------------------------------------------------------------------- #
# Forward kernel
# --------------------------------------------------------------------------- #
def _fp_kernel(params_ref,            # SMEM (n_views, 6)
               g_ref,                 # VMEM (NG, 1, bv) volume line
               out_ref,               # VMEM (ba, bu, bv) sino tile
               *, W: int, u0: float, du: float, ng: int, bu: int, bv: int,
               ba: int):
    """One program: for ``ba`` consecutive views, contract a (bu, W) footprint
    tile against the same (W, bv) volume window on the MXU.

    Angle-blocking (ba > 1) is the §Perf-CT hillclimb: the volume line
    g[:, l, vblock] — the dominant HBM stream — is fetched ONCE per program
    and reused for all ba views, dividing volume traffic by ba."""
    ab = pl.program_id(0)
    ub = pl.program_id(1)
    li = pl.program_id(3)

    @pl.when(li == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    u_first = u0 + (ub * bu) * du
    u_last = u_first + (bu - 1) * du

    for j in range(ba):
        a = ab * ba + j
        P = params_ref[a, 0]
        Q = params_ref[a, 1]
        R = params_ref[a, 2]
        hs = params_ref[a, 3]
        hd = params_ref[a, 4]
        h = params_ref[a, 5]

        gi_a = (u_first - R - Q * lif) / P
        gi_b = (u_last - R - Q * lif) / P
        start = jnp.floor(jnp.minimum(gi_a, gi_b)).astype(jnp.int32) - (
            W - jnp.abs(jnp.ceil(gi_b - gi_a)).astype(jnp.int32)) // 2
        start = jnp.clip(start, 0, max(ng - W, 0))

        win = g_ref[pl.ds(start, W), 0, :]                 # (W, bv)
        gi_abs = start.astype(jnp.float32) + jax.lax.broadcasted_iota(
            jnp.float32, (1, W), 1)                        # (1, W)
        uc = P * gi_abs + Q * lif + R                      # (1, W)
        uk = u_first + du * jax.lax.broadcasted_iota(jnp.float32, (bu, 1), 0)
        el = uk - du / 2.0                                 # (bu, 1)
        wgt = trapezoid_pixel_weight(el, el + du,
                                     uc - hs, uc - hd, uc + hd, uc + hs, h)
        precision.store_tile(out_ref, j, jax.lax.dot_general(
            precision.cast_like(wgt, win), win, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))


def _run_fp_group(g, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bu: int, bv: int, ba: int = 1):
    """g: (nx, ny, NVp) volume with the lane axis already padded to a bv
    multiple (NVp lanes = packed batch * n_rows).  Callers guard against
    empty view groups."""
    if params.shape[0] == 0:
        raise ValueError(
            "empty view group reached the parallel Pallas kernel; callers "
            "(_fp_core/_bp_core) must skip groups with no views")
    if not gathered_x:
        g = jnp.swapaxes(g, 0, 1)
    ng, nl, nvp = g.shape
    na = params.shape[0]
    params, _, ba = _pad_views(params, ba)   # padded views dropped after
    nap = params.shape[0]
    nup = _round_up(geom.n_cols, bu)
    W = min(_window_size(geom, bu), ng)
    u0 = float(geom.u_coords()[0])
    grid = (nap // ba, nup // bu, nvp // bv, nl)
    kernel = functools.partial(_fp_kernel, W=W, u0=u0, du=geom.pixel_width,
                               ng=ng, bu=bu, bv=bv, ba=ba)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((ng, 1, bv),
                                   lambda ab, ub, vb, l, *_: (0, l, vb))],
            out_specs=pl.BlockSpec((ba, bu, bv),
                                   lambda ab, ub, vb, l, *_: (ab, ub, vb)),
        ),
        # The output buffer is the cross-step accumulator: always f32
        # regardless of the tile dtype (the caller restores its dtype once).
        out_shape=jax.ShapeDtypeStruct((nap, nup, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), g)
    return out[:na]


def _fp_core(g, geom: CTGeometry, cfg: tune.KernelConfig):
    """g: (nx, ny, NV) lane-packed axial-footprint volume (NV lanes carry
    batch x n_rows).  Returns the u-major sinogram (n_angles, n_cols, NV)."""
    nv_lanes = g.shape[2]
    nvp = _round_up(nv_lanes, cfg.bv)
    g = jnp.pad(g, ((0, 0), (0, 0), (0, nvp - nv_lanes)))
    px, py, order = _view_params(geom)
    outs = []
    if px.shape[0]:
        outs.append(_run_fp_group(g, px, geom, True, cfg.bu, cfg.bv, cfg.ba))
    if py.shape[0]:
        outs.append(_run_fp_group(g, py, geom, False, cfg.bu, cfg.bv, cfg.ba))
    out = jnp.concatenate(outs, axis=0)                    # (na, NUp, NVp)
    out = out[:, :geom.n_cols, :nv_lanes]
    inv = np.argsort(order)
    return out[inv]


def fp_parallel_sf_pallas(f, geom: CTGeometry, bu: Optional[int] = None,
                          bv: Optional[int] = None, ba: Optional[int] = None,
                          config: Optional[tune.KernelConfig] = None,
                          compute_dtype=None):
    """f: (nx, ny, nz) -> sino (n_angles, n_rows, n_cols), or lane-packed
    batched f: (batch, nx, ny, nz) -> (batch, n_angles, n_rows, n_cols).
    ``compute_dtype`` selects the tile dtype at the VMEM boundary
    (None = follow ``f.dtype``); accumulation is always f32 and the result
    comes back in ``f.dtype``."""
    if f.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D volume, got {f.shape}")
    batch = f.shape[0] if f.ndim == 4 else 1
    out_dtype = f.dtype
    cdt = precision.resolve(compute_dtype, f.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bu=bu, bv=bv, ba=ba)
    Fz = jnp.asarray(_z_overlap_matrix(geom))              # (nz, nv)
    if f.ndim == 3:
        g = jnp.einsum("xyz,zv->xyv", f, Fz)               # axial footprint
        g = precision.cast_in(g, cdt)
        out = _fp_core(g, geom, cfg)                       # (na, nu, nv) f32
        return jnp.swapaxes(out, 1, 2).astype(out_dtype)   # (na, nv, nu)
    # Lane-packed batch: (B, nx, ny, nz) -> lanes = B * n_rows
    g = jnp.einsum("bxyz,zv->xybv", f, Fz)                 # (nx, ny, B, nv)
    g = g.reshape(geom.vol.nx, geom.vol.ny, batch * geom.n_rows)
    g = precision.cast_in(g, cdt)
    out = _fp_core(g, geom, cfg)                           # (na, nu, B*nv)
    out = out.reshape(geom.n_angles, geom.n_cols, batch, geom.n_rows)
    return jnp.transpose(out, (2, 0, 3, 1)).astype(out_dtype)


# --------------------------------------------------------------------------- #
# Backprojection kernel (exact transpose)
# --------------------------------------------------------------------------- #
def _bp_kernel(params_ref,            # SMEM (n_views, 6)
               q_ref,                 # VMEM (bab, NU, bv) sino stripes (u-major)
               out_ref,               # VMEM (bs*bg, 1, bv) volume tile
               *, Wu: int, u0: float, du: float, nu: int, bg: int, bv: int,
               bab: int, bs: int):
    """One program: accumulate ``bab`` views into ``bs`` consecutive
    (bg, bv) volume sub-tiles.

    View-blocking (bab > 1) mirrors the forward kernel's ``ba``: the ``bab``
    sinogram stripes arrive in a single wide DMA and the output tile is
    read-modify-written once per block instead of once per view.

    Stripe reuse (bs > 1) blocks the gathered axis: while a stripe is
    resident in VMEM (double-buffered by the Pallas pipeline) it serves
    ``bs`` gathered-axis sub-tiles before eviction, dividing sinogram
    traffic by ``bs``.  Each sub-tile keeps its own ``Wu`` detector window
    (sized by ``bg``), so weight tiles do not widen with ``bs``."""
    gb = pl.program_id(0)
    li = pl.program_id(1)
    ab = pl.program_id(3)

    @pl.when(ab == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    subs = [jnp.zeros((bg, bv), jnp.float32) for _ in range(bs)]
    for j in range(bab):
        a = ab * bab + j
        P = params_ref[a, 0]
        Q = params_ref[a, 1]
        R = params_ref[a, 2]
        hs = params_ref[a, 3]
        hd = params_ref[a, 4]
        h = params_ref[a, 5]

        for sj in range(bs):
            gi0 = (gb * bs + sj) * bg
            gi_abs = gi0 + jax.lax.broadcasted_iota(jnp.float32, (bg, 1), 0)
            uc_a = P * gi0 + Q * lif + R
            uc_b = P * (gi0 + bg - 1) + Q * lif + R
            ustart = jnp.floor(
                (jnp.minimum(uc_a, uc_b) - u0) / du).astype(jnp.int32) - (
                Wu - jnp.abs(jnp.ceil((uc_b - uc_a) / du)).astype(
                    jnp.int32)) // 2
            ustart = jnp.clip(ustart, 0, max(nu - Wu, 0))

            qwin = q_ref[j, pl.ds(ustart, Wu), :]          # (Wu, bv)
            uc = P * gi_abs + Q * lif + R                  # (bg, 1)
            uk = u0 + (ustart.astype(jnp.float32)
                       + jax.lax.broadcasted_iota(
                           jnp.float32, (1, Wu), 1)) * du
            el = uk - du / 2.0                             # (1, Wu)
            wgt = trapezoid_pixel_weight(el, el + du,
                                         uc - hs, uc - hd, uc + hd, uc + hs,
                                         h)
            subs[sj] = subs[sj] + jax.lax.dot_general(
                precision.cast_like(wgt, qwin), qwin, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
    acc = subs[0] if bs == 1 else jnp.concatenate(subs, axis=0)
    precision.store_tile(out_ref, (slice(None), 0, slice(None)), acc)


def _run_bp_group(q, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bg: int, bv: int, bab: int = 1, bs: int = 1):
    """q: (na_group, NUp, NVp) u-major sino slice for this view group.
    Returns the gathered-axis-major volume accumulator (NG, NL, NVp)."""
    ng, nl = ((geom.vol.nx, geom.vol.ny) if gathered_x
              else (geom.vol.ny, geom.vol.nx))
    na, nup, nvp = q.shape
    params, q, bab = _pad_views(params, bab, q)
    nap = params.shape[0]
    bs = max(1, min(bs, max(1, ng // bg)))    # don't block past the axis
    bstr = bg * bs                            # gathered voxels per program
    ngp = _round_up(ng, bstr)
    du, dx = geom.pixel_width, geom.vol.dx
    Wu = min(_round_up(int(math.ceil(bg * dx / du)) + 8, 8), nup)
    u0 = float(geom.u_coords()[0])
    grid = (ngp // bstr, nl, nvp // bv, nap // bab)
    kernel = functools.partial(_bp_kernel, Wu=Wu, u0=u0, du=du, nu=nup,
                               bg=bg, bv=bv, bab=bab, bs=bs)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((bab, nup, bv),
                                   lambda gb, l, vb, ab, *_: (ab, 0, vb))],
            out_specs=pl.BlockSpec((bstr, 1, bv),
                                   lambda gb, l, vb, ab, *_: (gb, l, vb)),
        ),
        # f32 cross-step accumulator regardless of the stripe dtype.
        out_shape=jax.ShapeDtypeStruct((ngp, nl, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), q)
    return out[:ng]


def _bp_core(q, geom: CTGeometry, cfg: tune.KernelConfig):
    """q: (n_angles, n_cols, NV) u-major lane-packed sinogram.  Returns the
    transaxial volume accumulator (nx, ny, NV) — axial transpose not yet
    applied."""
    nv_lanes = q.shape[2]
    nvp = _round_up(nv_lanes, cfg.bv)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, nvp - nv_lanes)))
    px, py, order = _view_params(geom)
    q = q[order]                                           # group-major order
    nax = px.shape[0]
    acc = jnp.zeros((geom.vol.nx, geom.vol.ny, nvp), jnp.float32)
    if nax:
        acc = acc + _run_bp_group(q[:nax], px, geom, True,
                                  cfg.bg, cfg.bv, cfg.bab, cfg.bs)
    if py.shape[0]:
        accy = _run_bp_group(q[nax:], py, geom, False,
                             cfg.bg, cfg.bv, cfg.bab, cfg.bs)
        acc = acc + jnp.swapaxes(accy, 0, 1)
    return acc[:, :, :nv_lanes]


def bp_parallel_sf_pallas(sino, geom: CTGeometry, bg: Optional[int] = None,
                          bv: Optional[int] = None, bab: Optional[int] = None,
                          bs: Optional[int] = None,
                          config: Optional[tune.KernelConfig] = None,
                          compute_dtype=None):
    """sino: (n_angles, n_rows, n_cols) -> volume (nx, ny, nz), or lane-packed
    batched sino: (batch, ...) -> (batch, nx, ny, nz).
    Exact transpose of ``fp_parallel_sf_pallas`` (incl. the batched path).
    ``compute_dtype`` selects the stripe dtype at the VMEM boundary; ``bs``
    overrides the stripe-reuse blocking factor."""
    if sino.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D sinogram, got {sino.shape}")
    batch = sino.shape[0] if sino.ndim == 4 else 1
    out_dtype = sino.dtype
    cdt = precision.resolve(compute_dtype, sino.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bg=bg, bv=bv, bab=bab, bs=bs)
    Fz = jnp.asarray(_z_overlap_matrix(geom))              # (nz, nv)
    if sino.ndim == 3:
        q = jnp.swapaxes(sino, 1, 2)                       # (na, nu, nv)
        q = precision.cast_in(q, cdt)
        acc = _bp_core(q, geom, cfg)                       # (nx, ny, nv) f32
        return jnp.einsum("xyv,zv->xyz", acc, Fz).astype(out_dtype)
    q = jnp.transpose(sino, (1, 3, 0, 2))                  # (na, nu, B, nv)
    q = q.reshape(geom.n_angles, geom.n_cols, batch * geom.n_rows)
    q = precision.cast_in(q, cdt)
    acc = _bp_core(q, geom, cfg)                           # (nx, ny, B*nv)
    acc = acc.reshape(geom.vol.nx, geom.vol.ny, batch, geom.n_rows)
    return jnp.einsum("xybv,zv->bxyz", acc, Fz).astype(out_dtype)


def register():
    from repro.kernels import ops
    ops.register_kernel("parallel", "sf", fp_parallel_sf_pallas,
                        bp_parallel_sf_pallas,
                        fp_batched=fp_parallel_sf_pallas,
                        bp_batched=bp_parallel_sf_pallas)

"""Kernel tile/block configuration: registry + heuristics + autotuner.

The Pallas projector kernels are parameterized by six tile sizes:

    bu   FP: detector-column tile (sublane axis of the output tile)
    bv   lane tile — the 128-wide axis.  With lane packing this axis holds
         ``batch * n_rows`` detector-row lanes, so thin-z training batches
         fill the MXU instead of padding it.
    ba   FP: views per program.  The volume line (the dominant HBM stream)
         is fetched once per program and reused for ``ba`` views.
    bg   BP: gathered-axis (voxel) tile.
    bab  BP: views per program — one wide sinogram-stripe DMA and a single
         output-tile accumulation per ``bab`` views.
    bs   BP: stripe reuse — gathered-axis sub-tiles served per sinogram
         stripe residency.  Each program covers ``bs * bg`` voxels, so one
         ``bab``-view stripe (double-buffered by the Pallas pipeline) is
         reused ``bs`` times before eviction instead of being re-fetched
         per gathered tile; the per-sub-tile detector window stays sized
         by ``bg``, so weight tiles do not widen.

Historically these were module constants (``BU``/``BV``); now every call
site resolves a :class:`KernelConfig` through :func:`get_config`:

    1. an explicit per-shape-class entry (``register_config`` or a previous
       autotune run), else
    2. a measured autotune sweep when running on real TPU hardware and
       autotune is enabled (``REPRO_AUTOTUNE=1`` or ``autotune=True``), else
    3. the heuristic table (always used in interpret mode / CPU).

Configs are keyed by a coarse *shape class*, not the exact geometry, so one
sweep serves every geometry of the same regime (e.g. all 2D limited-angle
training shapes share an entry).  The packed cone pair tunes as its own
``"cone-packed"`` regime (its kernel structure is the fan kernel's, not the
exact cone kernel's), and the modular pair as a ``"modular"`` regime with
cone-style heuristics (grid-folded views, rows tiled physically on the v
axis); this module also owns the ``mode="auto"`` dispatch
gate for it (:func:`packed_cone_ok`).  ``KernelConfig`` is frozen/hashable and is
part of the op-cache key in ``repro.kernels.ops`` — passing the same config
therefore reuses the cached (traced) ops instead of retracing.

Measured autotune results additionally persist to disk
(``~/.cache/repro/tune.json``, override the path with
``REPRO_TUNE_CACHE_PATH``), keyed by shape class + jax backend, so servers
skip the warmup sweep on restart.  ``REPRO_TUNE_CACHE=0`` disables the disk
cache entirely (reads and writes).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import pathlib
import time
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.geometry import CTGeometry

__all__ = [
    "KernelConfig",
    "shape_class",
    "get_config",
    "resolve_config",
    "register_config",
    "autotune",
    "clear",
    "cache_path",
    "save_tuned",
    "load_tuned",
    "packed_cone_tolerance",
    "packed_cone_ok",
]

LANE = 128          # TPU lane width: the bv axis should be a multiple of this
_SUBLANE = 8


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """Tile/block sizes for one (geometry-shape-class, kernel, dtype)."""

    bu: int = 16     # FP detector-column tile
    bv: int = LANE   # lane tile (packed batch * detector rows)
    ba: int = 1      # FP views per program
    bg: int = 16     # BP gathered-axis tile
    bab: int = 1     # BP views per program
    bs: int = 1      # BP gathered sub-tiles per stripe residency (reuse)

    def __post_init__(self):
        for name in ("bu", "bv", "ba", "bg", "bab", "bs"):
            v = getattr(self, name)
            if not (isinstance(v, int) and v > 0):
                raise ValueError(f"KernelConfig.{name} must be a positive "
                                 f"int, got {v!r}")
        if self.bv % _SUBLANE:
            raise ValueError(
                f"bv must be a multiple of {_SUBLANE}, got {self.bv} "
                f"(use {LANE} for full lane utilization on TPU)")

    def replace(self, **kw) -> "KernelConfig":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------- #
# Shape classes
# --------------------------------------------------------------------------- #
def _bucket(n: int) -> int:
    """Round up to the next power of two (coarse size bucketing)."""
    return 1 << max(0, int(n - 1).bit_length())


def _round_up8(n: int) -> int:
    return ((n + _SUBLANE - 1) // _SUBLANE) * _SUBLANE


def shape_class(geom: CTGeometry, batch: int = 1,
                dtype=jnp.float32, packed: bool = False) -> Tuple:
    """Coarse key identifying a kernel-tuning regime.

    Buckets the axes that drive tile choice: transaxial volume size, the
    detector-column count, the view count, and the *lane occupancy*
    ``batch * n_rows`` (what actually lands on the 128-wide axis after
    packing).  Exact geometry values (angles, spacings, shifts) do not
    change the optimal tiles and are deliberately excluded.

    ``packed`` marks the lane-packed cone pair (``fp_cone_packed``), whose
    kernel structure — and therefore optimal tiles — is the fan kernel's,
    not the exact cone kernel's; it tunes as its own regime.
    """
    lanes = batch * geom.n_rows
    kind = geom.geom_type + ("-packed" if packed else "")
    return (kind,
            _bucket(max(geom.vol.nx, geom.vol.ny)),
            _bucket(geom.n_cols),
            _bucket(geom.n_angles),
            _bucket(lanes),
            jnp.dtype(dtype).name)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_REGISTRY: Dict[Tuple, KernelConfig] = {}       # explicit + autotuned entries
_AUTOTUNED: Dict[Tuple, KernelConfig] = {}      # measured results only
_SWEEPS = 0                                     # autotune() invocations


def sweep_count() -> int:
    """Number of ``autotune`` invocations this process (warm-path probe:
    a primed serving instance must answer traffic without sweeping)."""
    return _SWEEPS


def register_config(cls_key: Tuple, cfg: KernelConfig) -> None:
    """Pin a config for a shape class (overrides heuristics and autotune)."""
    _REGISTRY[cls_key] = cfg


def clear() -> None:
    """Drop the in-process registries (the disk cache is left untouched)."""
    _REGISTRY.clear()
    _AUTOTUNED.clear()


# --------------------------------------------------------------------------- #
# Disk persistence (measured autotune results survive process restarts)
# --------------------------------------------------------------------------- #
def _disk_cache_enabled() -> bool:
    val = os.environ.get("REPRO_TUNE_CACHE", "1").strip().lower()
    return val not in ("", "0", "false", "no", "off")


def cache_path() -> pathlib.Path:
    """Location of the persisted tune cache (``REPRO_TUNE_CACHE_PATH`` or
    ``~/.cache/repro/tune.json``)."""
    p = os.environ.get("REPRO_TUNE_CACHE_PATH")
    if p:
        return pathlib.Path(p)
    return pathlib.Path.home() / ".cache" / "repro" / "tune.json"


def _disk_key(cls_key: Tuple) -> str:
    # Shape classes are flat tuples of strs/ints; the backend suffix keeps
    # TPU-measured configs from leaking onto other backends (and vice versa).
    return "|".join(str(x) for x in cls_key) + "@" + jax.default_backend()


def save_tuned(cls_key: Tuple, cfg: KernelConfig) -> None:
    """Best-effort persist of a measured config (no-op when disabled)."""
    if not _disk_cache_enabled():
        return
    path = cache_path()
    try:
        data = json.loads(path.read_text()) if path.exists() else {}
        if not isinstance(data, dict):
            data = {}
    except (OSError, ValueError):
        data = {}
    data[_disk_key(cls_key)] = dataclasses.asdict(cfg)
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_name(path.name + ".tmp")
        tmp.write_text(json.dumps(data, indent=2, sort_keys=True))
        os.replace(tmp, path)                     # atomic vs concurrent readers
    except OSError:
        pass                                      # cache is best-effort only


# Parsed-file memo keyed by (path, mtime_ns): get_config consults the disk
# cache on every registry miss, and without this every eager kernel call
# would re-read + re-parse the JSON file.  A save (here or by another
# process) bumps the mtime and invalidates the memo; a stat per call remains.
_DISK_MEMO: Dict[Tuple[str, int], dict] = {}


def _read_disk_cache() -> dict:
    path = cache_path()
    try:
        mtime = path.stat().st_mtime_ns
    except OSError:
        return {}
    memo_key = (str(path), mtime)
    if memo_key not in _DISK_MEMO:
        try:
            data = json.loads(path.read_text())
        except (OSError, ValueError):
            data = {}
        _DISK_MEMO.clear()                    # keep exactly one file cached
        _DISK_MEMO[memo_key] = data if isinstance(data, dict) else {}
    return _DISK_MEMO[memo_key]


def load_tuned(cls_key: Tuple) -> Optional[KernelConfig]:
    """Read a persisted config for this shape class + backend, or None."""
    if not _disk_cache_enabled():
        return None
    data = _read_disk_cache()
    raw = data.get(_disk_key(cls_key))
    if not isinstance(raw, dict):
        return None
    try:
        return KernelConfig(**{k: int(v) for k, v in raw.items()})
    except (TypeError, ValueError):
        return None                               # stale/foreign schema


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _autotune_enabled(flag: Optional[bool]) -> bool:
    if flag is not None:
        return flag
    val = os.environ.get("REPRO_AUTOTUNE", "0").strip().lower()
    return val not in ("", "0", "false", "no", "off")


def heuristic_config(geom: CTGeometry, batch: int = 1,
                     dtype=jnp.float32, packed: bool = False) -> KernelConfig:
    """Static table used off-TPU and as the autotune fallback/seed."""
    nu = geom.n_cols
    na = geom.n_angles
    # Column tile: big enough to keep the MXU sublane axis busy, small
    # enough that the gathered-axis window (which grows ~linearly in bu)
    # stays comfortably inside VMEM.
    bu = 8 if nu <= 16 else (16 if nu <= 512 else 32)
    bv = LANE
    if geom.geom_type == "cone" and packed:
        # The packed cone pair IS the fan kernel (the axial part is
        # pre-resampled outside): fan tiles, full 128-lane packing.
        bu = max(8, bu // 2)
    elif geom.geom_type in ("cone", "modular"):
        # The cone/modular kernels' gathered-axis window W grows with bu and
        # is walked by an inner loop — keep the column tile small.
        bu = 8
        # Cone/modular kernels tile *physical* detector rows on the v axis
        # (no lane packing; the BP's lane axis is z) — pad rows to the
        # sublane multiple instead of a full 128-lane tile.
        bv = min(_round_up8(max(geom.n_rows, 1)), LANE)
    elif geom.geom_type == "fan":
        # Fan is lane-packed like parallel, but its gathered-axis window is
        # magnified by sdd/(sod - R) — halve the column tile so the W-wide
        # VMEM window stays comparable to the parallel kernel's.
        bu = max(8, bu // 2)
    bg = bu
    # Stripe reuse only exists in the lane-packed BP kernels (parallel,
    # fan, packed cone); the view-folded cone/modular BPs ignore it.
    lane_packed_bp = geom.geom_type in ("parallel", "fan") or packed
    if _on_tpu():
        # View blocking amortizes the dominant HBM stream (volume line for
        # FP, sinogram stripe for BP); diminishing returns past ~8.
        ba = min(8 if na >= 8 else max(1, na), na)
        bab = min(4, na)
        # One stripe serving two gathered sub-tiles halves BP stripe
        # traffic for ~2x the output-tile VMEM — a safe default; autotune
        # sweeps 1/2/4.
        bs = 2 if lane_packed_bp else 1
    else:
        # Interpret mode executes the per-view python loop serially — keep
        # programs minimal so correctness tests stay fast.
        ba = 1
        bab = 1
        bs = 1
    return KernelConfig(bu=bu, bv=bv, ba=ba, bg=bg, bab=bab, bs=bs)


def get_config(geom: CTGeometry, batch: int = 1, dtype=jnp.float32,
               autotune_flag: Optional[bool] = None,
               packed: bool = False) -> KernelConfig:
    """Resolve the config for ``geom`` (see module docstring for the order)."""
    key = shape_class(geom, batch, dtype, packed)
    if key in _REGISTRY:
        return _REGISTRY[key]
    if key in _AUTOTUNED:
        return _AUTOTUNED[key]
    disk = load_tuned(key)
    if disk is not None:                  # persisted measurement: skip sweep
        _AUTOTUNED[key] = disk
        return disk
    if _on_tpu() and _autotune_enabled(autotune_flag):
        return autotune(geom, batch=batch, dtype=dtype, packed=packed)
    return heuristic_config(geom, batch, dtype, packed)


def resolve_config(geom: CTGeometry, batch: int,
                   config: Optional[KernelConfig],
                   dtype=jnp.float32, packed: bool = False,
                   **overrides) -> KernelConfig:
    """Shared entry-point resolution: an explicit ``config`` wins, else the
    registry/heuristics via :func:`get_config` (keyed on the input dtype);
    non-None keyword overrides (e.g. a caller's ``bu=8``) are applied last."""
    cfg = config if config is not None \
        else get_config(geom, batch=batch, dtype=dtype, packed=packed)
    kw = {k: v for k, v in overrides.items() if v is not None}
    return cfg.replace(**kw) if kw else cfg


# --------------------------------------------------------------------------- #
# Packed-cone dispatch gate
# --------------------------------------------------------------------------- #
# Default ceiling on the packed approximation's worst-case axial footprint
# displacement (detector rows).  A quarter row keeps the documented relative
# error bound (2x the shift + the second-order obliquity term, see
# fp_cone.cone_packed_error_bound) comfortably below typical detector noise.
PACKED_CONE_DEFAULT_TOL = 0.25


def packed_cone_tolerance() -> float:
    """Row-shift ceiling for ``mode="auto"`` packed-cone dispatch
    (``REPRO_PACKED_CONE_TOL`` overrides the default)."""
    val = os.environ.get("REPRO_PACKED_CONE_TOL", "").strip()
    if val:
        try:
            return float(val)
        except ValueError:
            # A typo'd tolerance silently falling back to the default would
            # dispatch approximate kernels at a looser gate than the user
            # asked for — make the misconfiguration loud instead.
            raise ValueError(
                f"REPRO_PACKED_CONE_TOL={val!r} is not a float") from None
    return PACKED_CONE_DEFAULT_TOL


def packed_cone_ok(geom: CTGeometry) -> bool:
    """True when the packed (lane-packed, axial pre-resample) cone pair is
    within tolerance for this geometry — the ``mode="auto"`` gate."""
    if geom.geom_type != "cone" or geom.detector_type != "flat":
        return False
    from repro.kernels import fp_cone                 # late: avoid cycle
    return fp_cone.cone_packed_row_shift(geom) <= packed_cone_tolerance()


# --------------------------------------------------------------------------- #
# Autotuner
# --------------------------------------------------------------------------- #
def _time_call(fn, *args, reps: int = 3) -> float:
    fn = jax.jit(fn)        # measure the fused program production runs
    out = fn(*args)                                   # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def default_candidates(geom: CTGeometry) -> Iterable[KernelConfig]:
    """The measured sweep grid: small, but covers the axes that matter."""
    na = geom.n_angles
    bus = [b for b in (8, 16, 32) if b <= max(_SUBLANE, geom.n_cols * 2)]
    bas = sorted({min(b, na) for b in (1, 2, 4, 8)})
    bgs = [8, 16, 32]
    babs = sorted({min(b, na) for b in (1, 2, 4)})
    bss = (1, 2, 4)                       # BP stripe-reuse blocking factors
    for bu, ba in itertools.product(bus, bas):
        for bg, bab, bs in itertools.product(bgs, babs, bss):
            yield KernelConfig(bu=bu, bv=LANE, ba=ba, bg=bg, bab=bab, bs=bs)


def autotune(geom: CTGeometry, batch: int = 1, dtype=jnp.float32,
             candidates: Optional[Iterable[KernelConfig]] = None,
             reps: int = 3, packed: bool = False) -> KernelConfig:
    """Measure candidate configs with the real kernels and cache the winner.

    Only meaningful on TPU (interpret-mode timings reflect the Python
    interpreter, not the hardware); elsewhere this returns the heuristic
    without measuring.  FP and BP are timed independently and the best
    (bu, ba) is combined with the best (bg, bab).
    """
    global _SWEEPS
    _SWEEPS += 1
    key = shape_class(geom, batch, dtype, packed)
    if not _on_tpu():
        cfg = heuristic_config(geom, batch, dtype, packed)
        _AUTOTUNED[key] = cfg
        return cfg

    from repro.kernels import fp_par                  # late: avoid cycle

    cand = list(candidates) if candidates is not None \
        else list(default_candidates(geom))
    if geom.geom_type == "cone" and packed:
        # The packed cone pair is structurally the fan kernel (lane-packed,
        # view-blocked) — run the same full fp/bp sweep on its entry points.
        from repro.kernels import fp_cone
        fp_fn, bp_fn = fp_cone.fp_cone_packed, fp_cone.bp_cone_packed
    elif geom.geom_type == "cone":
        # Cone has no FP view-blocking knob (views fold into the grid) but
        # a full Pallas BP: sweep the FP column tile and the BP (bg, bab).
        from repro.kernels import fp_cone
        return _autotune_viewfold(geom, batch, dtype, cand, reps, key,
                                  fp_cone.fp_cone_sf_pallas,
                                  fp_cone.bp_cone_sf_pallas)
    elif geom.geom_type == "modular":
        # Modular is structurally the exact cone pair (grid-folded views,
        # per-view frames prefetched): the same FP-bu x BP-(bg, bab) sweep
        # on the modular entry points.
        from repro.kernels import fp_modular
        return _autotune_viewfold(geom, batch, dtype, cand, reps, key,
                                  fp_modular.fp_modular_sf_pallas,
                                  fp_modular.bp_modular_sf_pallas)
    elif geom.geom_type == "fan":
        # Fan is Pallas end to end like parallel: same full fp/bp sweep.
        from repro.kernels import fp_fan
        fp_fn, bp_fn = fp_fan.fp_fan_sf_pallas, fp_fan.bp_fan_sf_pallas
    elif geom.geom_type == "parallel":
        fp_fn, bp_fn = fp_par.fp_parallel_sf_pallas, fp_par.bp_parallel_sf_pallas
    else:
        cfg = heuristic_config(geom, batch, dtype)
        _AUTOTUNED[key] = cfg
        return cfg
    fp_grid = sorted({(c.bu, c.ba) for c in cand})
    bp_grid = sorted({(c.bg, c.bab, c.bs) for c in cand})

    shape = ((batch,) if batch > 1 else ()) + geom.vol.shape
    f = jnp.ones(shape, dtype)
    sshape = ((batch,) if batch > 1 else ()) + geom.sino_shape
    y = jnp.ones(sshape, dtype)

    heur = heuristic_config(geom, batch, dtype, packed)
    best_fp, t_fp = None, float("inf")
    for bu, ba in fp_grid:
        cfg = KernelConfig(bu=bu, ba=ba)
        try:
            t = _time_call(lambda x: fp_fn(x, geom, config=cfg), f, reps=reps)
        except Exception:                             # noqa: BLE001
            continue                                  # invalid tiling — skip
        if t < t_fp:
            best_fp, t_fp = (bu, ba), t

    best_bp, t_bp = None, float("inf")
    for bg, bab, bs in bp_grid:
        cfg = KernelConfig(bg=bg, bab=bab, bs=bs)
        try:
            t = _time_call(lambda p: bp_fn(p, geom, config=cfg), y, reps=reps)
        except Exception:                             # noqa: BLE001
            continue
        if t < t_bp:
            best_bp, t_bp = (bg, bab, bs), t

    # Never cache an unmeasured candidate: if a sweep produced no successful
    # run, fall back to the heuristic for that kernel.
    cfg = KernelConfig(
        bu=best_fp[0] if best_fp else heur.bu,
        ba=best_fp[1] if best_fp else heur.ba,
        bg=best_bp[0] if best_bp else heur.bg,
        bab=best_bp[1] if best_bp else heur.bab,
        bs=best_bp[2] if best_bp else heur.bs)
    _AUTOTUNED[key] = cfg
    save_tuned(key, cfg)
    return cfg


def _autotune_viewfold(geom: CTGeometry, batch: int, dtype, cand, reps: int,
                       key: Tuple, fp_fn, bp_fn) -> KernelConfig:
    """Sweep for the grid-folded-view kernels (exact cone, modular): FP
    column tile (bu) + BP gathered tile / view block (bg, bab), mirroring
    the fan/parallel sweep.  The row tile bv stays on the heuristic (it
    tiles physical detector rows, whose count the shape class already
    encodes); there is no FP ``ba`` knob — views fold into the grid — and
    ``bs`` is not swept (the view-folded BPs ignore stripe blocking)."""
    base = heuristic_config(geom, batch, dtype)
    shape = ((batch,) if batch > 1 else ()) + geom.vol.shape
    f = jnp.ones(shape, dtype)
    sshape = ((batch,) if batch > 1 else ()) + geom.sino_shape
    y = jnp.ones(sshape, dtype)
    best_bu, t_best = base.bu, float("inf")
    for bu in sorted({c.bu for c in cand}):
        cfg = base.replace(bu=bu, ba=1)
        try:
            t = _time_call(lambda x: fp_fn(x, geom, config=cfg), f, reps=reps)
        except Exception:                             # noqa: BLE001
            continue
        if t < t_best:
            best_bu, t_best = bu, t
    best_bp, t_bp = None, float("inf")
    for bg, bab in sorted({(c.bg, c.bab) for c in cand}):
        cfg = base.replace(bg=bg, bab=bab)
        try:
            t = _time_call(lambda p: bp_fn(p, geom, config=cfg), y, reps=reps)
        except Exception:                             # noqa: BLE001
            continue
        if t < t_bp:
            best_bp, t_bp = (bg, bab), t
    cfg = base.replace(bu=best_bu, ba=1,
                       bg=best_bp[0] if best_bp else base.bg,
                       bab=best_bp[1] if best_bp else base.bab)
    _AUTOTUNED[key] = cfg
    save_tuned(key, cfg)
    return cfg

"""Pallas TPU kernel: cone-beam (flat detector) Separable-Footprint forward
projection.

Same TPU-native pattern as the parallel kernel (``fp_par.py``): per program a
``(BU columns) x (BV rows)`` output tile for one view; loop over the volume
loop-axis; per step, a W-wide window along the gathered axis.  Two cone-beam
specifics:

* the transaxial footprint is the *exact corner projection* trapezoid —
  ``u = sdd * q / ell`` with q, ell affine in the voxel index, evaluated for
  the four voxel corners and sorted with min/max ops (all vectorized over W);
* the axial footprint magnifies per gathered element: for each window
  element w, the BV detector rows pull from a z-window of the volume line
  via an on-the-fly (BV x NZW) rect-overlap matrix (iota-built) and one MXU
  matvec — this is the per-element axial resample that makes cone beams
  non-separable on TPU (DESIGN.md §2).

Backprojection (``bp_cone_sf_pallas``) is the *exact transpose* of the
forward kernel, so the registered pair is matched on-kernel end to end:

* transaxial: the same corner-projected trapezoid breakpoints
  (``_corner_trapezoid``, shared between FP/BP and the fan kernels),
  contracted in the transposed direction — a (BG, Wu) weight tile against a
  (Wu, BV) sinogram window gathered with a scalar-prefetched ``pl.ds``;
* axial: the per-element rect-overlap matvec runs in the adjoint direction —
  each gathered element's (BV, nz) overlap matrix maps its u-contracted
  detector rows back onto the volume's z lanes on the MXU.

``bp_cone_sf_ref`` (the jnp-oracle adjoint) is kept as the cross-check
oracle for ``tests/test_kernels.py``.

Batching: the *exact* kernels' per-lane axial resample depends on the actual
detector-row coordinate of each lane, so batch cannot be packed into the
128-wide axis the way the parallel kernel does.  Instead a leading batch
dimension is folded into the *view* grid axis (FP) / the *gathered-output*
grid axis (BP) — the per-view parameter table stays shared across samples,
so one ``pallas_call`` covers the whole batch (no vmap over the kernel).

For small cone angles the **packed pair** (``fp_cone_packed`` /
``bp_cone_packed``) removes the obstacle: detector rows are pre-resampled
onto volume z-planes at the central magnification *outside* the kernel
(``_z_overlap_cone_packed``), the transaxial remainder is exactly the fan
kernel, and ``batch x n_rows`` lane packing applies directly.  The
approximation carries a derived per-geometry error bound
(``cone_packed_error_bound``) that gates ``mode="auto"`` dispatch in
``repro.kernels.ops`` (see docs/KERNELS.md "Packed cone pair").

Tile sizes come from :mod:`repro.kernels.tune` (``KernelConfig``).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.geometry import CTGeometry
from repro.kernels import precision, ref, tune
from repro.kernels.footprint import trapezoid_pixel_weight


_EPS = 1e-9


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def _mag_bounds(geom: CTGeometry) -> Tuple[float, float]:
    """(mag_min, mag_max) transaxial magnification over the volume disk."""
    r = geom.vol.radius
    mag_max = geom.sdd / max(geom.sod - r, 1e-3)
    mag_min = geom.sdd / (geom.sod + r)
    return mag_min, mag_max


def _u_window_size_div(geom: CTGeometry, bg: int, nu: int) -> int:
    """Static bound on the detector-column window covering one bg voxel tile
    for a *divergent* (fan / cone transaxial) beam (BP kernels).
    |duc/dgi| <= sqrt(2) * dx * mag_max and one voxel footprint spans
    <= sqrt(2) * dx * mag_max; curved (fan) footprints are never wider."""
    du, dx = geom.pixel_width, geom.vol.dx
    _, mag_max = _mag_bounds(geom)
    span = bg * dx * math.sqrt(2.0) * mag_max / du
    margin = 2.0 * math.sqrt(2.0) * dx * mag_max / du + 4.0
    w = int(math.ceil(span + 2 * margin)) + 2
    return min(_round_up(max(w, 8), 8), nu)


def _corner_trapezoid(P, gi, q0, l0, lif, sdd, dxv, curved: bool = False):
    """Corner-projection trapezoid breakpoints + amplitude + squared
    transaxial ray length for gathered indices ``gi`` (broadcast shape).

    ``P`` is the 20-float per-view parameter row of ``_view_params_cone``.
    Shared by the cone FP/BP kernels and the fan kernels (``fp_fan.py``) so
    every evaluation of the same (view, gi, li) triple produces identical
    weights — the exact-transpose requirement of the matched pair."""
    Aq, Al = P[0], P[3]
    q = Aq * gi + q0
    ell = Al * gi + l0
    taus = []
    for k in range(4):
        dq, dl = P[12 + 2 * k], P[13 + 2 * k]
        lc = jnp.maximum(ell + dl, _EPS)
        if curved:
            taus.append(sdd * jnp.arctan2(q + dq, lc))
        else:
            taus.append(sdd * (q + dq) / lc)
    m1 = jnp.minimum(taus[0], taus[1])
    M1 = jnp.maximum(taus[0], taus[1])
    m2 = jnp.minimum(taus[2], taus[3])
    M2 = jnp.maximum(taus[2], taus[3])
    t0 = jnp.minimum(m1, m2)
    t3 = jnp.maximum(M1, M2)
    ta, tb = jnp.maximum(m1, m2), jnp.minimum(M1, M2)
    t1 = jnp.minimum(ta, tb)
    t2 = jnp.maximum(ta, tb)
    Arx, Brx, Crx, Ary, Bry, Cry = P[6:12]
    rx = Arx * gi + Brx * lif + Crx
    ry = Ary * gi + Bry * lif + Cry
    rt2 = rx * rx + ry * ry
    h = dxv * jnp.sqrt(rt2) / jnp.maximum(
        jnp.maximum(jnp.abs(rx), jnp.abs(ry)), _EPS)
    return t0, t1, t2, t3, h, rt2


def _view_params_cone(geom: CTGeometry) -> Tuple[np.ndarray, np.ndarray,
                                                 np.ndarray]:
    """Per-view affine coefficients of q(gi, li) and ell(gi, li) plus the
    four corner offsets (dq_k, dl_k) and the rx/ry affines, split into the
    x-gathered (|sin|>=|cos|) and y-gathered groups.

    Layout per view (20 floats):
      [Aq, Bq, Cq, Al, Bl, Cl, Arx, Brx, Crx, Ary, Bry, Cry,
       dq0, dl0, dq1, dl1, dq2, dl2, dq3, dl3]
    """
    v = geom.vol
    ang = geom.angles_array()
    c, s = np.cos(ang), np.sin(ang)
    x0, y0 = float(v.x_coords()[0]), float(v.y_coords()[0])
    sod = geom.sod
    hx, hy = v.dx / 2.0, v.dy / 2.0

    def grp(gathered_x: bool):
        if gathered_x:
            # gi -> x, li -> y
            Aq, Bq = -s * v.dx, c * v.dy
            Al, Bl = -c * v.dx, -s * v.dy
            Arx, Brx = v.dx * np.ones_like(c), np.zeros_like(c)
            Ary, Bry = np.zeros_like(c), v.dy * np.ones_like(c)
        else:
            Aq, Bq = c * v.dy, -s * v.dx
            Al, Bl = -s * v.dy, -c * v.dx
            Arx, Brx = np.zeros_like(c), v.dx * np.ones_like(c)
            Ary, Bry = v.dy * np.ones_like(c), np.zeros_like(c)
        Cq = c * y0 - s * x0
        Cl = sod - (c * x0 + s * y0)
        Crx = x0 - sod * c
        Cry = y0 - sod * s
        cols = [Aq, Bq, Cq, Al, Bl, Cl, Arx, Brx, Crx, Ary, Bry, Cry]
        for sx in (-hx, hx):
            for sy in (-hy, hy):
                cols.append(c * sy - s * sx)            # dq
                cols.append(-(c * sx + s * sy))         # dl
        return np.stack(cols, -1).astype(np.float32)

    gx = np.abs(s) >= np.abs(c)
    px, py = grp(True), grp(False)
    idx_x = np.nonzero(gx)[0]
    idx_y = np.nonzero(~gx)[0]
    return px[idx_x], py[idx_y], np.concatenate([idx_x, idx_y])


def _fp_cone_kernel(params_ref,        # SMEM (n_views, 20)
                    f_ref,             # VMEM (NG, 1, NZ) volume line
                    out_ref,           # VMEM (1, BU, BV) sino tile
                    *, W: int, NZW: int, u0: float, du: float,
                    v0: float, dv: float, z0c: float, dz: float,
                    sdd: float, dxv: float, ng: int, nz: int,
                    bu: int, bv: int, nav: int):
    a = pl.program_id(0)
    ub = pl.program_id(1)
    vb = pl.program_id(2)
    li = pl.program_id(3)

    @pl.when(li == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # Batched runs fold the batch into the view grid axis; the params table
    # stays (n_views, 20) in SMEM and the view index wraps per sample.
    av = jax.lax.rem(a, nav)
    P = [params_ref[av, i] for i in range(20)]
    Aq, Bq, Cq, Al, Bl, Cl = P[:6]
    lif = li.astype(jnp.float32)
    u_first = u0 + (ub * bu) * du
    u_last = u_first + (bu - 1) * du

    # window start: invert u = sdd*(Aq*gi + q0)/(Al*gi + l0)
    q0 = Bq * lif + Cq
    l0 = Bl * lif + Cl

    def gi_of(u):
        den = sdd * Aq - u * Al
        den = jnp.where(jnp.abs(den) > 1e-6, den, 1e-6)
        return (u * l0 - sdd * q0) / den

    g1, g2 = gi_of(u_first), gi_of(u_last)
    start = jnp.floor(jnp.minimum(g1, g2)).astype(jnp.int32) - (
        W - jnp.abs(jnp.ceil(g2 - g1)).astype(jnp.int32)) // 2
    start = jnp.clip(start, 0, max(ng - W, 0))

    gi = start.astype(jnp.float32) + jax.lax.broadcasted_iota(
        jnp.float32, (1, W), 1)                              # (1, W)
    # corner projections -> sorted trapezoid breakpoints (shared with BP)
    t0, t1, t2, t3, h, rt2 = _corner_trapezoid(P, gi, q0, l0, lif, sdd, dxv)

    uk = u_first + du * jax.lax.broadcasted_iota(jnp.float32, (bu, 1), 0)
    el = uk - du / 2.0
    wu = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)  # (bu, W)

    ell = jnp.maximum(Al * gi + l0, _EPS)
    mag = sdd / ell                                          # (1, W)
    v_first = v0 + (vb * bv) * dv
    vlane = v_first + dv * jax.lax.broadcasted_iota(jnp.float32, (bv, 1), 0)

    acc = jnp.zeros((bu, bv), jnp.float32)
    for w in range(W):
        mag_w = mag[0, w]
        rt2_w = rt2[0, w]
        inv_mag = 1.0 / jnp.maximum(mag_w, 1e-9)
        # z index window covering this view-row block at this magnification
        zc_first = v_first * inv_mag
        z0i = jnp.floor((zc_first - z0c) / dz).astype(jnp.int32) - 2
        z0i = jnp.clip(z0i, 0, max(nz - NZW, 0))
        zt = z0c + (z0i.astype(jnp.float32)
                    + jax.lax.broadcasted_iota(jnp.float32, (1, NZW), 1)) * dz
        vlo = (zt - dz / 2.0) * mag_w                        # (1, NZW)
        vhi = (zt + dz / 2.0) * mag_w
        elv = vlane - dv / 2.0                               # (bv, 1)
        ov = jnp.maximum(jnp.minimum(vhi, elv + dv)
                         - jnp.maximum(vlo, elv), 0.0) / dv  # (bv, NZW)
        obl = jnp.sqrt(1.0 + (zt * zt) / jnp.maximum(rt2_w, 1e-9))
        Wz = ov * obl                                        # (bv, NZW)
        fwin = f_ref[start + w, 0, pl.ds(z0i, NZW)]          # (NZW,)
        rv = jax.lax.dot_general(precision.cast_like(Wz, fwin), fwin[:, None],
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)[:, 0]
        acc = acc + wu[:, w][:, None] * rv[None, :]
    precision.store_tile(out_ref, 0, acc)


def _run_group(fb, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
               bu: int, bv: int):
    """fb: (B, nx, ny, nz) batch of volumes.  The batch is folded into the
    view grid axis: grid step ``a`` covers view ``a % na`` of sample
    ``a // na`` (volumes stacked along the gathered axis; the SMEM params
    table is *not* duplicated per sample).  Returns (B, na_group, NUp, NVp)."""
    if params.shape[0] == 0:
        return None
    vol = geom.vol
    if not gathered_x:
        fb = jnp.swapaxes(fb, 1, 2)
    B, ng, nl, nz = fb.shape
    fs = fb.reshape(B * ng, nl, nz)
    na = params.shape[0]
    nup = _round_up(geom.n_cols, bu)
    nvp = _round_up(geom.n_rows, bv)
    mag_min, mag_max = _mag_bounds(geom)
    span = bu * geom.pixel_width * math.sqrt(2.0) / (vol.dx * mag_min)
    margin = 2.0 * (math.sqrt(2.0) * vol.dx * mag_max
                    + geom.pixel_width) / (vol.dx * mag_min) + 4.0
    W = min(int(math.ceil(span + 2 * margin)) + 2, ng)
    NZW = min(int(math.ceil(bv * geom.pixel_height / (mag_min * vol.dz)))
              + 6, nz)
    kernel = functools.partial(
        _fp_cone_kernel, W=W, NZW=NZW,
        u0=float(geom.u_coords()[0]), du=geom.pixel_width,
        v0=float(geom.v_coords()[0]), dv=geom.pixel_height,
        z0c=float(vol.z_coords()[0]), dz=vol.dz,
        sdd=geom.sdd, dxv=vol.dx, ng=ng, nz=nz, bu=bu, bv=bv, nav=na)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * na, nup // bu, nvp // bv, nl),
            in_specs=[pl.BlockSpec((ng, 1, nz),
                                   lambda a, ub, vb, l, *_: (a // na, l, 0))],
            out_specs=pl.BlockSpec((1, bu, bv),
                                   lambda a, ub, vb, l, *_: (a, ub, vb)),
        ),
        # output buffer is the cross-step accumulator: always f32
        out_shape=jax.ShapeDtypeStruct((B * na, nup, nvp), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), fs)
    return out.reshape(B, na, nup, nvp)


def fp_cone_sf_pallas(f, geom: CTGeometry, bu: Optional[int] = None,
                      bv: Optional[int] = None,
                      config: Optional[tune.KernelConfig] = None,
                      compute_dtype=None):
    """f: (nx, ny, nz) -> sino (n_angles, n_rows, n_cols), or batched
    f: (batch, nx, ny, nz) -> (batch, ...).  Flat detector."""
    if geom.geom_type != "cone" or geom.detector_type != "flat":
        raise ValueError(
            f"fp_cone_sf_pallas needs a flat-detector cone geometry, got "
            f"geom_type={geom.geom_type!r} detector_type="
            f"{getattr(geom, 'detector_type', None)!r}; curved-detector "
            f"cone runs through the ref backend")
    if f.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D volume, got {f.shape}")
    batched = f.ndim == 4
    out_dtype = f.dtype
    cdt = precision.resolve(compute_dtype, f.dtype)
    fb = precision.cast_in(f if batched else f[None], cdt)
    cfg = tune.resolve_config(geom, fb.shape[0], config, dtype=cdt,
                              bu=bu, bv=bv)
    px, py, order = _view_params_cone(geom)
    outs = []
    o1 = _run_group(fb, px, geom, True, cfg.bu, cfg.bv)
    if o1 is not None:
        outs.append(o1)
    o2 = _run_group(fb, py, geom, False, cfg.bu, cfg.bv)
    if o2 is not None:
        outs.append(o2)
    out = jnp.concatenate(outs, axis=1)                    # (B, na, NUp, NVp)
    out = out[:, :, :geom.n_cols, :geom.n_rows]
    inv = np.argsort(order)
    out = jnp.swapaxes(out[:, inv], 2, 3).astype(out_dtype)  # (B, na, nv, nu)
    return out if batched else out[0]


# --------------------------------------------------------------------------- #
# Backprojection kernel (exact transpose)
# --------------------------------------------------------------------------- #
def _bp_cone_kernel(params_ref,        # SMEM (n_views, 20)
                    q_ref,             # VMEM (bab, NU, bv) u-major sino stripes
                    out_ref,           # VMEM (bg, 1, nz) volume tile (z lanes)
                    *, Wu: int, u0: float, du: float, v0: float, dv: float,
                    z0c: float, dz: float, sdd: float, dxv: float,
                    nu: int, nz: int, bg: int, bv: int, bab: int, ngb: int):
    """One program: accumulate ``bab`` views x ``bv`` detector rows into one
    (bg gathered elements, nz) volume tile — the exact transpose of
    ``_fp_cone_kernel``:

    * transaxial: the same corner-projected breakpoints, contracted in the
      transposed direction ((bg, Wu) weights x (Wu, bv) sinogram window);
    * axial: each gathered element's (bv, nz) rect-overlap matrix (same
      iota construction as the forward's z-window, evaluated over the full
      z line since the output lanes *are* z) maps its u-contracted detector
      rows back onto the volume line via one MXU matvec per element.
    """
    gall = pl.program_id(0)
    li = pl.program_id(1)
    vb = pl.program_id(2)
    ab = pl.program_id(3)

    @pl.when((vb == 0) & (ab == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lif = li.astype(jnp.float32)
    # Batched runs fold the batch into the gathered-output grid axis; the
    # params table stays (n_views, 20) in SMEM shared across samples.
    gi0 = jax.lax.rem(gall, ngb) * bg
    gi_abs = gi0.astype(jnp.float32) + jax.lax.broadcasted_iota(
        jnp.float32, (bg, 1), 0)                             # (bg, 1)
    v_first = v0 + (vb * bv) * dv
    elv = v_first - dv / 2.0 + dv * jax.lax.broadcasted_iota(
        jnp.float32, (bv, 1), 0)                             # (bv, 1)
    zt = z0c + dz * jax.lax.broadcasted_iota(jnp.float32, (1, nz), 1)

    acc = jnp.zeros((bg, nz), jnp.float32)
    for j in range(bab):
        a = ab * bab + j
        P = [params_ref[a, i] for i in range(20)]
        Aq, Bq, Cq, Al, Bl, Cl = P[:6]
        q0 = Bq * lif + Cq
        l0 = Bl * lif + Cl

        # window start: center projection u(gi) over the gathered tile
        def uc_of(gi):
            qg = Aq * gi + q0
            lg = jnp.maximum(Al * gi + l0, _EPS)
            return sdd * qg / lg

        uc_a = uc_of(gi0.astype(jnp.float32))
        uc_b = uc_of((gi0 + bg - 1).astype(jnp.float32))
        ustart = jnp.floor(
            (jnp.minimum(uc_a, uc_b) - u0) / du).astype(jnp.int32) - (
            Wu - jnp.abs(jnp.ceil((uc_b - uc_a) / du)).astype(jnp.int32)) // 2
        ustart = jnp.clip(ustart, 0, max(nu - Wu, 0))

        qwin = q_ref[j, pl.ds(ustart, Wu), :]                # (Wu, bv)
        t0, t1, t2, t3, h, rt2 = _corner_trapezoid(
            P, gi_abs, q0, l0, lif, sdd, dxv)                # (bg, 1)
        uk = u0 + (ustart.astype(jnp.float32)
                   + jax.lax.broadcasted_iota(jnp.float32, (1, Wu), 1)) * du
        el = uk - du / 2.0                                   # (1, Wu)
        wgt = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
        rows = jax.lax.dot_general(precision.cast_like(wgt, qwin),
                                   qwin,                     # (bg, bv)
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)
        # Transposed per-element axial resample: every gathered element has
        # its own magnification, so its bv u-contracted detector rows map
        # through an element-specific (bv, nz) overlap matrix onto z lanes.
        zcols = []
        for g in range(bg):
            ell_g = jnp.maximum(Al * gi_abs[g, 0] + l0, _EPS)
            mag_g = sdd / ell_g
            vlo = (zt - dz / 2.0) * mag_g                    # (1, nz)
            vhi = (zt + dz / 2.0) * mag_g
            ov = jnp.maximum(jnp.minimum(vhi, elv + dv)
                             - jnp.maximum(vlo, elv), 0.0) / dv   # (bv, nz)
            obl = jnp.sqrt(1.0 + (zt * zt) / jnp.maximum(rt2[g, 0], _EPS))
            Wz = ov * obl                                    # (bv, nz)
            zcols.append(jax.lax.dot_general(
                rows[g][None, :], Wz, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32))         # (1, nz)
        acc = acc + jnp.concatenate(zcols, axis=0)
    precision.store_tile(out_ref, (slice(None), 0, slice(None)), acc)


def _run_bp_group(q, params: np.ndarray, geom: CTGeometry, gathered_x: bool,
                  bg: int, bv: int, bab: int):
    """q: (B, na_group, n_cols, n_rows) u-major sino slice for this view
    group.  The batch is folded into the gathered-output grid axis (the
    transpose of the FP's view-axis folding).  Returns the gathered-axis-
    major volume accumulator (B, NG, NL, nz)."""
    vol = geom.vol
    ng, nl = (vol.nx, vol.ny) if gathered_x else (vol.ny, vol.nx)
    nz = vol.nz
    B, na, nu_, nv_ = q.shape
    bab = max(1, min(bab, na))
    nap = _round_up(na, bab)
    if nap != na:
        params = np.concatenate([params, np.repeat(params[-1:],
                                                   nap - na, 0)], 0)
        q = jnp.pad(q, ((0, 0), (0, nap - na), (0, 0), (0, 0)))
    nvp = _round_up(nv_, bv)
    q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, nvp - nv_)))
    qs = q.reshape(B * nap, nu_, nvp)
    ngp = _round_up(ng, bg)
    ngb, nab = ngp // bg, nap // bab
    Wu = _u_window_size_div(geom, bg, nu_)
    kernel = functools.partial(
        _bp_cone_kernel, Wu=Wu,
        u0=float(geom.u_coords()[0]), du=geom.pixel_width,
        v0=float(geom.v_coords()[0]), dv=geom.pixel_height,
        z0c=float(vol.z_coords()[0]), dz=vol.dz, sdd=geom.sdd, dxv=vol.dx,
        nu=nu_, nz=nz, bg=bg, bv=bv, bab=bab, ngb=ngb)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B * ngb, nl, nvp // bv, nab),
            in_specs=[pl.BlockSpec((bab, nu_, bv),
                                   lambda gall, l, vb, ab, *_:
                                   (gall // ngb * nab + ab, 0, vb))],
            out_specs=pl.BlockSpec((bg, 1, nz),
                                   lambda gall, l, vb, ab, *_: (gall, l, 0)),
        ),
        # output buffer is the cross-step accumulator: always f32
        out_shape=jax.ShapeDtypeStruct((B * ngp, nl, nz), jnp.float32),
        interpret=_interpret(),
    )(jnp.asarray(params), qs)
    return out.reshape(B, ngp, nl, nz)[:, :ng]


def bp_cone_sf_pallas(sino, geom: CTGeometry, bg: Optional[int] = None,
                      bv: Optional[int] = None, bab: Optional[int] = None,
                      config: Optional[tune.KernelConfig] = None,
                      compute_dtype=None):
    """sino: (n_angles, n_rows, n_cols) -> volume (nx, ny, nz), or batched
    sino: (batch, ...) -> (batch, nx, ny, nz).  Flat detector.

    Exact transpose of ``fp_cone_sf_pallas`` (incl. the batched path): same
    corner-projection trapezoid via the transposed contraction, and the
    per-element axial rect-overlap matvec applied in the adjoint direction
    (detector rows -> volume z lanes)."""
    if geom.geom_type != "cone" or geom.detector_type != "flat":
        raise ValueError(
            f"bp_cone_sf_pallas needs a flat-detector cone geometry, got "
            f"geom_type={geom.geom_type!r} detector_type="
            f"{getattr(geom, 'detector_type', None)!r}; curved-detector "
            f"cone runs through the ref backend")
    if sino.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D sinogram, got {sino.shape}")
    batched = sino.ndim == 4
    out_dtype = sino.dtype
    cdt = precision.resolve(compute_dtype, sino.dtype)
    qb = sino if batched else sino[None]
    cfg = tune.resolve_config(geom, qb.shape[0], config, dtype=cdt,
                              bg=bg, bv=bv, bab=bab)
    px, py, order = _view_params_cone(geom)
    q = jnp.swapaxes(qb, 2, 3)                             # (B, na, nu, nv)
    q = precision.cast_in(q[:, order], cdt)                # group-major views
    nax = px.shape[0]
    acc = jnp.zeros((qb.shape[0],) + geom.vol.shape, jnp.float32)
    if nax:
        acc = acc + _run_bp_group(q[:, :nax], px, geom, True,
                                  cfg.bg, cfg.bv, cfg.bab)
    if py.shape[0]:
        accy = _run_bp_group(q[:, nax:], py, geom, False,
                             cfg.bg, cfg.bv, cfg.bab)
        acc = acc + jnp.swapaxes(accy, 1, 2)
    acc = acc.astype(out_dtype)
    return acc if batched else acc[0]


# --------------------------------------------------------------------------- #
# Packed (lane-packed) cone pair: small-cone-angle axial pre-resample
# --------------------------------------------------------------------------- #
def _z_overlap_cone_packed(geom: CTGeometry) -> np.ndarray:
    """(nz, nv) axial pre-resample matrix at the *central* magnification.

    The exact cone kernel resamples each volume z-line onto detector rows at
    the per-voxel magnification ``sdd/ell`` — that per-lane dependence is
    what blocks lane packing.  The packed approximation freezes the
    magnification at its rotation-axis value ``mag0 = sdd/sod`` (and the
    axial obliquity at the central ray's ``sqrt(1 + z^2/sod^2)``), making
    the z -> detector-row map voxel-independent: it becomes one (nz, nv)
    rect-overlap matrix applied *outside* the kernel, exactly like the
    parallel/fan axial separation.  The remaining transaxial contraction is
    the fan kernel verbatim, so batch x n_rows lane packing applies.

    Error: a z-plane at height ``z`` lands ``z * (sdd/ell - mag0)`` mm from
    its exact row; see :func:`cone_packed_row_shift` for the worst case.
    """
    v = geom.vol
    mag0 = geom.sdd / geom.sod
    dv = geom.pixel_height
    zc = v.z_coords().astype(np.float64)[:, None]            # (nz, 1)
    ve = geom.v_coords().astype(np.float64)[None, :]         # (1, nv)
    vlo = (zc - v.dz / 2.0) * mag0
    vhi = (zc + v.dz / 2.0) * mag0
    ov = np.maximum(np.minimum(vhi, ve + dv / 2.0)
                    - np.maximum(vlo, ve - dv / 2.0), 0.0) / dv
    obl = np.sqrt(1.0 + (zc / geom.sod) ** 2)                # central ray
    return (ov * obl).astype(np.float32)


def _z_edge_extent(geom: CTGeometry) -> float:
    """|z| of the outermost voxel *edge* (mm) — the worst-case height."""
    v = geom.vol
    return v.nz * v.dz / 2.0 + abs(v.offset_z)


def half_cone_tangent(geom: CTGeometry) -> float:
    """tan of the half-cone angle subtended by the volume's z extent at the
    source (``z_max / sod`` — the small parameter of the approximation)."""
    return _z_edge_extent(geom) / geom.sod


def cone_packed_row_shift(geom: CTGeometry) -> float:
    """Worst-case axial footprint displacement of the packed approximation,
    in *detector-row units*.

    A voxel at transaxial source distance ``ell`` projects its z-extent at
    magnification ``sdd/ell``; the packed matrix uses ``mag0 = sdd/sod``.
    Over the volume disk ``ell`` ranges in [sod - R, sod + R], so a footprint
    edge at height ``z`` is displaced by at most::

        |z| * max(sdd/(sod-R) - mag0, mag0 - sdd/(sod+R))
          =  z_max * mag0 * R / (sod - R)        [mm on the detector]

    Equivalently ``tan(theta_half) * sdd * R / (sod - R)`` with theta_half
    the half-cone angle — the shift is *first order* in the cone angle and
    vanishes in the fan limit.
    """
    r = geom.vol.radius
    mag0 = geom.sdd / geom.sod
    dmag = max(geom.sdd / max(geom.sod - r, 1e-3) - mag0,
               mag0 - geom.sdd / (geom.sod + r))
    return _z_edge_extent(geom) * dmag / geom.pixel_height


def cone_packed_error_bound(geom: CTGeometry) -> float:
    """Documented bound on the relative L2 sinogram error of the packed
    pair vs the exact cone pair (docs/KERNELS.md derives it).

    Two mismatch sources, both functions of the half-cone angle:

    * footprint displacement: every (voxel, z-plane) row-overlap window
      shifts by at most ``s = cone_packed_row_shift(geom)`` rows, and the
      normalized rect-overlap weights are 2-Lipschitz in the shift (a box
      edge moves through at most ``s`` rows on each side), giving a
      relative weight perturbation <= 2 s;
    * obliquity: ``sqrt(1 + z^2/ell_t^2)`` is evaluated at ``ell_t = sod``
      instead of the true transaxial distance, a relative error of at most
      ``0.5 * tan(theta_half)^2 * ((sod/(sod-R))^2 - 1)`` (second order).
    """
    r = geom.vol.radius
    s = cone_packed_row_shift(geom)
    t = half_cone_tangent(geom)
    obl = 0.5 * (t ** 2) * ((geom.sod / max(geom.sod - r, 1e-3)) ** 2 - 1.0)
    return 2.0 * s + obl


def fp_cone_packed(f, geom: CTGeometry, bu: Optional[int] = None,
                   bv: Optional[int] = None, ba: Optional[int] = None,
                   config: Optional[tune.KernelConfig] = None,
                   compute_dtype=None):
    """Lane-packed cone forward projection (axial pre-resample).

    f: (nx, ny, nz) -> sino (n_angles, n_rows, n_cols), or batched
    f: (batch, nx, ny, nz) -> (batch, ...) with ``batch * n_rows`` detector
    rows folded onto the 128-lane axis (the fan kernel's packing, applied to
    the cone transaxial footprint).  Valid for small cone angles — callers
    go through ``ops``/``Projector`` ``mode=`` dispatch, which gates on
    :func:`cone_packed_error_bound`."""
    if geom.geom_type != "cone" or geom.detector_type != "flat":
        raise NotImplementedError(
            "packed cone pair supports flat-detector cone geometries only, "
            f"got {geom.geom_type}/{geom.detector_type}")
    if f.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D volume, got {f.shape}")
    from repro.kernels import fp_fan                 # late: fan imports us
    batch = f.shape[0] if f.ndim == 4 else 1
    out_dtype = f.dtype
    cdt = precision.resolve(compute_dtype, f.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bu=bu, bv=bv, ba=ba, packed=True)
    Fz = jnp.asarray(_z_overlap_cone_packed(geom))             # (nz, nv)
    if f.ndim == 3:
        g = jnp.einsum("xyz,zv->xyv", f, Fz)                   # pre-resample
        out = fp_fan._fp_core(precision.cast_in(g, cdt), geom, cfg)
        return jnp.swapaxes(out, 1, 2).astype(out_dtype)       # (na, nv, nu)
    g = jnp.einsum("bxyz,zv->xybv", f, Fz)                     # (nx, ny, B, nv)
    g = g.reshape(geom.vol.nx, geom.vol.ny, batch * geom.n_rows)
    out = fp_fan._fp_core(precision.cast_in(g, cdt), geom, cfg)
    out = out.reshape(geom.n_angles, geom.n_cols, batch, geom.n_rows)
    return jnp.transpose(out, (2, 0, 3, 1)).astype(out_dtype)  # (B, na, nv, nu)


def bp_cone_packed(sino, geom: CTGeometry, bg: Optional[int] = None,
                   bv: Optional[int] = None, bab: Optional[int] = None,
                   bs: Optional[int] = None,
                   config: Optional[tune.KernelConfig] = None,
                   compute_dtype=None):
    """Exact transpose of ``fp_cone_packed`` (incl. the batched path): the
    fan BP kernel's transposed transaxial contraction followed by the
    transposed axial pre-resample einsum."""
    if geom.geom_type != "cone" or geom.detector_type != "flat":
        raise NotImplementedError(
            "packed cone pair supports flat-detector cone geometries only, "
            f"got {geom.geom_type}/{geom.detector_type}")
    if sino.ndim not in (3, 4):
        raise ValueError(f"expected 3D or batched 4D sinogram, got {sino.shape}")
    from repro.kernels import fp_fan                 # late: fan imports us
    batch = sino.shape[0] if sino.ndim == 4 else 1
    out_dtype = sino.dtype
    cdt = precision.resolve(compute_dtype, sino.dtype)
    cfg = tune.resolve_config(geom, batch, config, dtype=cdt,
                              bg=bg, bv=bv, bab=bab, bs=bs, packed=True)
    Fz = jnp.asarray(_z_overlap_cone_packed(geom))             # (nz, nv)
    if sino.ndim == 3:
        q = precision.cast_in(jnp.swapaxes(sino, 1, 2), cdt)   # (na, nu, nv)
        acc = fp_fan._bp_core(q, geom, cfg)                    # (nx, ny, nv)
        return jnp.einsum("xyv,zv->xyz", acc, Fz).astype(out_dtype)
    q = jnp.transpose(sino, (1, 3, 0, 2))                      # (na, nu, B, nv)
    q = q.reshape(geom.n_angles, geom.n_cols, batch * geom.n_rows)
    acc = fp_fan._bp_core(precision.cast_in(q, cdt), geom, cfg)
    acc = acc.reshape(geom.vol.nx, geom.vol.ny, batch, geom.n_rows)
    return jnp.einsum("xybv,zv->bxyz", acc, Fz).astype(out_dtype)


def fp_cone_packed_ref(f, geom: CTGeometry):
    """jnp oracle for the packed pair: the fan transaxial oracle with the
    central-magnification axial pre-resample — differentiable, runs
    everywhere, and the cross-check for ``fp_cone_packed``."""
    return ref.fp_fan_sf(f, geom, z_overlap=_z_overlap_cone_packed(geom))


def bp_cone_packed_ref(sino, geom: CTGeometry):
    """Exact linear transpose of the packed oracle (via jax.vjp)."""
    f0 = jnp.zeros(geom.vol.shape, sino.dtype)
    _, vjp = jax.vjp(lambda x: fp_cone_packed_ref(x, geom), f0)
    return vjp(sino)[0]


def bp_cone_sf_ref(sino, geom: CTGeometry,
                   config: Optional[tune.KernelConfig] = None):
    """Adjoint via the jnp oracle (exact transpose of the oracle forward).
    Kept as the cross-check oracle for the Pallas BP kernel; the registered
    pair uses ``bp_cone_sf_pallas``."""
    return ref.adjoint(sino, geom, "sf")


def bp_cone_sf_ref_batched(sino, geom: CTGeometry,
                           config: Optional[tune.KernelConfig] = None):
    """Batched oracle adjoint (vmap over the jnp oracle)."""
    return jax.vmap(lambda q: ref.adjoint(q, geom, "sf"))(sino)


def register():
    from repro.kernels import ops
    ops.register_kernel("cone", "sf", fp_cone_sf_pallas, bp_cone_sf_pallas,
                        fp_batched=fp_cone_sf_pallas,
                        bp_batched=bp_cone_sf_pallas,
                        fp_packed=fp_cone_packed,
                        bp_packed=bp_cone_packed,
                        packed_ok=tune.packed_cone_ok)

"""Pure-jnp reference projectors (oracles).

These are fully differentiable, jit-able implementations of the forward
X-ray transform for every geometry x model combination the library supports.
They serve three roles:

1. Oracle for the Pallas TPU kernels (``tests/test_kernels.py`` asserts
   allclose against these across shape/dtype sweeps — ``forward`` for the
   FP kernels, ``adjoint`` for the Pallas backprojectors).
2. CPU fallback backend (this is what actually executes in this container).
3. Source of *matched adjoints*: backprojection is obtained with
   ``jax.linear_transpose`` of the forward map, which is the exact transpose
   by construction — the paper's matched-projector-pair requirement.

Models:
    * ``joseph`` — driving-axis linear interpolation (Joseph 1982).  Replaces
      LEAP's Siddon fast path; Siddon's per-ray voxel-crossing enumeration is
      GPU-warp idiomatic and has no efficient TPU analogue (see DESIGN.md).
    * ``sf``     — Separable Footprint (Long et al. 2010), the accurate model.

All functions map ``f (nx, ny, nz) -> sino (n_angles, n_rows, n_cols)`` and
are linear in ``f``.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.core.geometry import CTGeometry
from repro.kernels import precision
from repro.kernels.footprint import (cone_transaxial_footprint,
                                     fan_transaxial_footprint,
                                     parallel_footprint, rect_overlap,
                                     trapezoid_pixel_weight)

_EPS = 1e-9


# --------------------------------------------------------------------------- #
# Small helpers
# --------------------------------------------------------------------------- #
def _lerp_take(arr, pos, axis):
    """Linearly interpolate ``arr`` along ``axis`` at float positions ``pos``.

    ``pos`` must have the same ndim as ``arr`` with size 1 along dims it
    broadcasts over.  Out-of-range positions contribute zero."""
    n = arr.shape[axis]
    j = jnp.floor(pos)
    w = pos - j
    j = j.astype(jnp.int32)
    valid0 = (j >= 0) & (j <= n - 1)
    valid1 = (j + 1 >= 0) & (j + 1 <= n - 1)
    j0 = jnp.clip(j, 0, n - 1)
    j1 = jnp.clip(j + 1, 0, n - 1)
    a0 = jnp.take_along_axis(arr, j0, axis=axis, mode="clip")
    a1 = jnp.take_along_axis(arr, j1, axis=axis, mode="clip")
    return a0 * jnp.where(valid0, 1.0 - w, 0.0) + a1 * jnp.where(valid1, w, 0.0)


def _grids(geom: CTGeometry):
    v = geom.vol
    return (jnp.asarray(v.x_coords()), jnp.asarray(v.y_coords()),
            jnp.asarray(v.z_coords()), jnp.asarray(geom.u_coords()),
            jnp.asarray(geom.v_coords()))


def _z_overlap_matrix(geom: CTGeometry) -> np.ndarray:
    """(nz, nv) rectangle-overlap weights for parallel beam (axial separable)."""
    v = geom.vol
    zc = v.z_coords()[:, None]                       # (nz, 1)
    ve = geom.v_coords()[None, :]                    # (1, nv) pixel centers
    lo = np.maximum(zc - v.dz / 2, ve - geom.pixel_height / 2)
    hi = np.minimum(zc + v.dz / 2, ve + geom.pixel_height / 2)
    return (np.maximum(hi - lo, 0.0) / geom.pixel_height).astype(np.float32)


# --------------------------------------------------------------------------- #
# Parallel beam
# --------------------------------------------------------------------------- #
def fp_parallel_joseph(f, geom: CTGeometry):
    xs, ys, zs, us, vs = _grids(geom)
    v = geom.vol
    nx, ny, nz = v.shape
    nu = geom.n_cols

    # axial: z(v) is angle-independent for parallel beam
    zi = (vs - v.offset_z) / v.dz + (nz - 1) / 2.0   # (nv,)

    def one_angle(_, ang):
        c, s = jnp.cos(ang), jnp.sin(ang)
        drive_x = jnp.abs(c) >= jnp.abs(s)
        # --- drive along x: y = x tan + u / cos
        ypos = xs[:, None] * (s / jnp.where(drive_x, c, 1.0)) \
            + us[None, :] / jnp.where(drive_x, c, 1.0)          # (nx, nu)
        yi = (ypos - v.offset_y) / v.dy + (ny - 1) / 2.0
        gx = _lerp_take(f, jnp.broadcast_to(yi[:, :, None], (nx, nu, 1)), axis=1)
        sx = jnp.sum(gx, axis=0) * (v.dx / jnp.maximum(jnp.abs(c), _EPS))  # (nu, nz)
        # --- drive along y: x = y cot - u / sin
        xpos = ys[:, None] * (c / jnp.where(drive_x, 1.0, s)) \
            - us[None, :] / jnp.where(drive_x, 1.0, s)          # (ny, nu)
        xi = (xpos - v.offset_x) / v.dx + (nx - 1) / 2.0
        fT = jnp.swapaxes(f, 0, 1)                               # (ny, nx, nz)
        gy = _lerp_take(fT, jnp.broadcast_to(xi[:, :, None], (ny, nu, 1)), axis=1)
        sy = jnp.sum(gy, axis=0) * (v.dy / jnp.maximum(jnp.abs(s), _EPS))  # (nu, nz)
        srow = jnp.where(drive_x, sx, sy)                        # (nu, nz)
        # axial interpolation to detector rows
        p = _lerp_take(srow, jnp.broadcast_to(zi[None, :], (nu, geom.n_rows)),
                       axis=1)                                   # (nu, nv)
        return 0, p.T                                            # (nv, nu)

    _, sino = jax.lax.scan(one_angle, 0, jnp.asarray(geom.angles_array()))
    return sino


def fp_parallel_sf(f, geom: CTGeometry):
    xs, ys, zs, us, vs = _grids(geom)
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    du = geom.pixel_width
    Fz = jnp.asarray(_z_overlap_matrix(geom))                    # (nz, nv)
    g = jnp.einsum("xyz,zv->xyv", f, Fz).reshape(nx * ny, nv)    # axial first
    X = jnp.asarray(np.repeat(geom.vol.x_coords(), ny))
    Y = jnp.asarray(np.tile(geom.vol.y_coords(), nx))
    K = geom.max_footprint_cols()
    edge0 = float(geom.u_coords()[0]) - du / 2.0

    def one_angle(_, ang):
        c, s = jnp.cos(ang), jnp.sin(ang)
        uc = Y * c - X * s                                       # (nx*ny,)
        t0, t1, t2, t3, h = parallel_footprint(uc, c, s, v.dx)
        k0 = jnp.floor((t0 - edge0) / du + 1e-4).astype(jnp.int32)
        acc = jnp.zeros((nu, nv), f.dtype)
        for k in range(K):
            iu = k0 + k
            el = edge0 + iu.astype(f.dtype) * du
            w = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
            ok = (iu >= 0) & (iu < nu)
            w = jnp.where(ok, w, 0.0)
            acc = acc.at[jnp.clip(iu, 0, nu - 1)].add(w[:, None] * g)
        return 0, acc.T                                          # (nv, nu)

    _, sino = jax.lax.scan(one_angle, 0, jnp.asarray(geom.angles_array()))
    return sino


# --------------------------------------------------------------------------- #
# Fan beam (flat = equispaced columns, curved = equiangular arc)
# --------------------------------------------------------------------------- #
def fp_fan_sf(f, geom: CTGeometry, z_overlap=None):
    """Separable-footprint fan beam: exact corner-projection trapezoid in the
    transaxial direction x the parallel (angle-independent) rectangle overlap
    axially — the cone model with the axial magnification collapsed.

    ``z_overlap`` substitutes a custom (nz, nv) axial matrix; the packed
    cone oracle (``fp_cone.fp_cone_packed_ref``) passes its central-
    magnification pre-resample here, reusing the transaxial math."""
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    du = geom.pixel_width
    sod, sdd = geom.sod, geom.sdd
    curved = geom.detector_type == "curved"
    Fz = jnp.asarray(_z_overlap_matrix(geom) if z_overlap is None
                     else z_overlap)                             # (nz, nv)
    g = jnp.einsum("xyz,zv->xyv", f, Fz).reshape(nx * ny, nv)    # axial first
    X = jnp.asarray(np.repeat(v.x_coords(), ny))
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    K = geom.max_footprint_cols()
    edge0 = float(geom.u_coords()[0]) - du / 2.0

    def one_angle(_, ang):
        c, s = jnp.cos(ang), jnp.sin(ang)
        t0, t1, t2, t3, h, _ell = fan_transaxial_footprint(
            X, Y, c, s, sod, sdd, v.dx, curved)
        # Same 1e-4 nudge as the cone oracle: keeps floor off exact bin
        # boundaries where XLA fusion rewrites can flip it by one pixel.
        k0 = jnp.floor((t0 - edge0) / du + 1e-4).astype(jnp.int32)
        acc = jnp.zeros((nu, nv), f.dtype)
        for k in range(K):
            iu = k0 + k
            el = edge0 + iu.astype(f.dtype) * du
            w = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
            ok = (iu >= 0) & (iu < nu)
            w = jnp.where(ok, w, 0.0)
            acc = acc.at[jnp.clip(iu, 0, nu - 1)].add(w[:, None] * g)
        return 0, acc.T                                          # (nv, nu)

    _, sino = jax.lax.scan(one_angle, 0, jnp.asarray(geom.angles_array()))
    return sino


# --------------------------------------------------------------------------- #
# Cone beam (axial, flat or curved detector)
# --------------------------------------------------------------------------- #
def fp_cone_joseph(f, geom: CTGeometry):
    xs, ys, zs, us, vs = _grids(geom)
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    sod, sdd = geom.sod, geom.sdd
    curved = geom.detector_type == "curved"

    def one_angle(_, ang):
        c, s = jnp.cos(ang), jnp.sin(ang)
        sx, sy = sod * c, sod * s
        if curved:
            gam = us / sdd
            dirx = sdd * (-c * jnp.cos(gam) - s * jnp.sin(gam))
            diry = sdd * (-s * jnp.cos(gam) + c * jnp.sin(gam))
        else:
            dirx = -sdd * c - us * s
            diry = -sdd * s + us * c
        drive_x = jnp.abs(c) >= jnp.abs(s)

        def project(fv, axis_coords, other_offset, other_d, n_other,
                    src_a, src_b, dir_a, dir_b, da):
            # drive along axis `a`; interpolate along axis `b` then z.
            t = (axis_coords[:, None] - src_a) / jnp.where(
                jnp.abs(dir_a) > _EPS, dir_a, _EPS)[None, :]      # (na_, nu)
            bpos = src_b + t * dir_b[None, :]
            bi = (bpos - other_offset) / other_d + (n_other - 1) / 2.0
            A = _lerp_take(fv, jnp.broadcast_to(bi[:, :, None],
                                                (fv.shape[0], nu, 1)), axis=1)
            # axial: z = t * v   (source z = 0, dir_z = v)
            zi_ = (t[:, :, None] * vs[None, None, :] - v.offset_z) / v.dz \
                + (nz - 1) / 2.0                                  # (na_, nu, nv)
            B = _lerp_take(A, zi_, axis=2)                        # (na_, nu, nv)
            tin = (t > 0.0) & (t < 1.0)
            B = B * tin[:, :, None]
            # ray-length weight: (nu, nv)
            wt = da * jnp.sqrt((dir_a ** 2 + dir_b ** 2)[:, None]
                               + vs[None, :] ** 2) / jnp.maximum(
                jnp.abs(dir_a), _EPS)[:, None]
            return jnp.sum(B, axis=0) * wt                        # (nu, nv)

        px = project(f, xs, v.offset_y, v.dy, ny, sx, sy, dirx, diry, v.dx)
        py = project(jnp.swapaxes(f, 0, 1), ys, v.offset_x, v.dx, nx,
                     sy, sx, diry, dirx, v.dy)
        p = jnp.where(drive_x, px, py)
        return 0, p.T                                             # (nv, nu)

    _, sino = jax.lax.scan(one_angle, 0, jnp.asarray(geom.angles_array()))
    return sino


def fp_cone_sf(f, geom: CTGeometry):
    if geom.detector_type != "flat":
        raise NotImplementedError("SF cone supports flat detectors; "
                                  "use joseph for curved")
    xs, ys, zs, us, vs = _grids(geom)
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    du, dv = geom.pixel_width, geom.pixel_height
    sod, sdd = geom.sod, geom.sdd
    Ku = geom.max_footprint_cols()
    Kv = geom.max_footprint_rows()
    uedge0 = float(geom.u_coords()[0]) - du / 2.0
    vedge0 = float(geom.v_coords()[0]) - dv / 2.0
    X = jnp.asarray(np.repeat(v.x_coords(), ny))                 # (nxy,)
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    Z = jnp.asarray(v.z_coords())                                # (nz,)
    fflat = f.reshape(nx * ny, nz)

    def one_angle(_, ang):
        c, s = jnp.cos(ang), jnp.sin(ang)
        t0, t1, t2, t3, h, ell = cone_transaxial_footprint(X, Y, c, s, sod, sdd, v.dx)
        # 3D obliquity at voxel center (per z)
        rx, ry = X - sod * c, Y - sod * s
        rt2 = rx * rx + ry * ry
        obl = jnp.sqrt(1.0 + (Z[None, :] ** 2) / jnp.maximum(rt2[:, None], _EPS))
        # axial rectangle: v in [sdd*(z-dz/2)/ell, sdd*(z+dz/2)/ell]
        mag = sdd / jnp.maximum(ell, _EPS)                       # (nxy,)
        vlo = (Z[None, :] - v.dz / 2) * mag[:, None]             # (nxy, nz)
        vhi = (Z[None, :] + v.dz / 2) * mag[:, None]
        # The +1e-4 nudge keeps the floor argument off exact bin
        # boundaries: XLA CPU fusion may recompute the fused expression with
        # FMA/reciprocal rewrites that differ from the materialized value by
        # 1 ulp, flipping the floor and shifting the whole footprint window
        # by one pixel (eager != jit; found by the Pallas cone kernel's
        # oracle cross-check — see EXPERIMENTS.md).  At a boundary the
        # overlap with the dropped bin is exactly zero, so the nudge only
        # removes the ambiguity (error <= 1e-4 pixel).
        ku0 = jnp.floor((t0 - uedge0) / du + 1e-4).astype(jnp.int32)
        kv0 = jnp.floor((vlo - vedge0) / dv + 1e-4).astype(jnp.int32)
        vals = fflat * obl                                       # (nxy, nz)
        acc = jnp.zeros((nv * nu,), f.dtype)
        for ku in range(Ku):
            iu = ku0 + ku
            el = uedge0 + iu.astype(f.dtype) * du
            wu = trapezoid_pixel_weight(el, el + du, t0, t1, t2, t3, h)
            oku = (iu >= 0) & (iu < nu)
            wu = jnp.where(oku, wu, 0.0)
            iuc = jnp.clip(iu, 0, nu - 1)                        # (nxy,)
            for kv in range(Kv):
                iv = kv0 + kv                                    # (nxy, nz)
                elv = vedge0 + iv.astype(f.dtype) * dv
                wv = rect_overlap(vlo, vhi, elv, elv + dv)
                okv = (iv >= 0) & (iv < nv)
                wv = jnp.where(okv, wv, 0.0)
                ivc = jnp.clip(iv, 0, nv - 1)
                idx = ivc * nu + iuc[:, None]                    # (nxy, nz)
                acc = acc + jax.ops.segment_sum(
                    (vals * wu[:, None] * wv).reshape(-1),
                    idx.reshape(-1), num_segments=nv * nu)
        return 0, acc.reshape(nv, nu)

    _, sino = jax.lax.scan(one_angle, 0, jnp.asarray(geom.angles_array()))
    return sino


# --------------------------------------------------------------------------- #
# Modular beam (arbitrary source/detector pose) — generic ray marching Joseph
# --------------------------------------------------------------------------- #
def fp_modular_joseph(f, geom: CTGeometry, oversample: float = 2.0):
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    us = jnp.asarray(geom.u_coords())
    vs = jnp.asarray(geom.v_coords())
    n_steps = int(np.ceil(oversample * np.sqrt(3) * max(v.shape)))
    bmin = jnp.asarray([v.x_coords()[0] - v.dx / 2,
                        v.y_coords()[0] - v.dy / 2,
                        v.z_coords()[0] - v.dz / 2])
    bmax = jnp.asarray([v.x_coords()[-1] + v.dx / 2,
                        v.y_coords()[-1] + v.dy / 2,
                        v.z_coords()[-1] + v.dz / 2])
    off = jnp.asarray([v.offset_x, v.offset_y, v.offset_z])
    dd = jnp.asarray([v.dx, v.dy, v.dz])
    nn = jnp.asarray([nx, ny, nz])
    fflat = f.reshape(-1)

    def one_view(_, view):
        src, ctr, eu, ev = view
        d = (ctr[None, None, :] + us[None, :, None] * eu[None, None, :]
             + vs[:, None, None] * ev[None, None, :])             # (nv, nu, 3)
        dirv = d - src[None, None, :]
        inv = 1.0 / jnp.where(jnp.abs(dirv) > _EPS, dirv, _EPS)
        ta = (bmin[None, None, :] - src[None, None, :]) * inv
        tb = (bmax[None, None, :] - src[None, None, :]) * inv
        tmin = jnp.max(jnp.minimum(ta, tb), axis=-1)
        tmax = jnp.min(jnp.maximum(ta, tb), axis=-1)
        tmin = jnp.maximum(tmin, 0.0)
        seg = jnp.maximum(tmax - tmin, 0.0)                       # (nv, nu)
        dt = seg / n_steps
        dlen = jnp.linalg.norm(dirv, axis=-1)                     # (nv, nu)

        def step(acc, k):
            t = tmin + (k + 0.5) * dt
            pt = src[None, None, :] + t[:, :, None] * dirv        # (nv, nu, 3)
            fi = (pt - off[None, None, :]) / dd + (nn - 1) / 2.0
            j = jnp.floor(fi).astype(jnp.int32)
            w = fi - j
            val = jnp.zeros(t.shape, f.dtype)
            for cx in (0, 1):
                for cy in (0, 1):
                    for cz in (0, 1):
                        jj = j + jnp.asarray([cx, cy, cz])
                        ok = jnp.all((jj >= 0) & (jj < nn), axis=-1)
                        jjc = jnp.clip(jj, 0, nn - 1)
                        flat = (jjc[..., 0] * ny + jjc[..., 1]) * nz + jjc[..., 2]
                        ww = (jnp.where(cx, w[..., 0], 1 - w[..., 0])
                              * jnp.where(cy, w[..., 1], 1 - w[..., 1])
                              * jnp.where(cz, w[..., 2], 1 - w[..., 2]))
                        val += jnp.take(fflat, flat.reshape(-1)).reshape(t.shape) \
                            * ww * ok
            return acc + val, 0

        acc, _ = jax.lax.scan(step, jnp.zeros((nv, nu), f.dtype),
                              jnp.arange(n_steps))
        return 0, acc * dt * dlen

    views = (jnp.asarray(geom.source_pos), jnp.asarray(geom.det_center),
             jnp.asarray(geom.det_u), jnp.asarray(geom.det_v))
    _, sino = jax.lax.scan(one_view, 0, views)
    return sino


# --------------------------------------------------------------------------- #
# Dispatch + matched adjoints
# --------------------------------------------------------------------------- #
_FP_TABLE = {
    ("parallel", "joseph"): fp_parallel_joseph,
    ("parallel", "sf"): fp_parallel_sf,
    ("fan", "sf"): fp_fan_sf,
    ("cone", "joseph"): fp_cone_joseph,
    ("cone", "sf"): fp_cone_sf,
    ("modular", "joseph"): fp_modular_joseph,
}


def register_reference(geom_type: str, model: str, fn) -> None:
    """Add a reference projector to the dispatch table.  Kernel modules that
    also own a jnp oracle (``fp_modular.fp_modular_sf_ref``) register it
    here so the table's ownership stays in this module; ``adjoint`` picks
    the entry up automatically (the vjp of any registered forward is its
    exact transpose)."""
    _FP_TABLE[(geom_type, model)] = fn


def _quantize_in(x, dtype):
    """Dtype-matched-oracle input handling: quantize the *data* to the
    compute dtype (matching the kernels' tile cast) but run the oracle math
    in f32 — the oracle's coordinate/weight arithmetic follows the input
    dtype, and detector-edge coordinates at bf16's 8-bit mantissa would
    corrupt the footprint geometry the kernels always derive in f32.
    Returns (f32 quantized data, original dtype) or (x, None) when the
    plain f32 path applies unchanged."""
    cdt = precision.resolve(dtype, x.dtype)
    if cdt == jnp.float32 and x.dtype == jnp.float32:
        return x, None
    return x.astype(cdt).astype(jnp.float32), x.dtype


def forward(f, geom: CTGeometry, model: str = "sf", dtype=None):
    """Reference forward projection.  ``dtype`` mirrors the kernels'
    ``compute_dtype`` policy so oracles stay dtype-matched: the volume is
    quantized to the compute dtype, the math runs in f32, and the result
    comes back in the input's dtype."""
    key = (geom.geom_type, model)
    if key not in _FP_TABLE:
        if geom.geom_type == "modular":
            # ("modular", "sf") is injected by fp_modular.register() when
            # the kernels package imports; before that (or for unknown
            # models) modular falls back to the Joseph ray-marcher.
            key = ("modular", "joseph")
        else:
            raise NotImplementedError(f"no reference projector for {key}")
    fq, out_dtype = _quantize_in(f, dtype)
    out = _FP_TABLE[key](fq, geom)
    return out if out_dtype is None else out.astype(out_dtype)


def adjoint(sino, geom: CTGeometry, model: str = "sf", dtype=None):
    """Exact-transpose backprojection: A^T applied to ``sino``.

    ``forward`` is linear in the volume, so its VJP *is* the exact adjoint —
    the matched-pair property holds by construction.  ``dtype`` applies the
    same quantize-data-only policy as :func:`forward`."""
    q, out_dtype = _quantize_in(sino, dtype)
    f0 = jnp.zeros(geom.vol.shape, q.dtype)
    _, vjp = jax.vjp(lambda x: forward(x, geom, model), f0)
    out = vjp(q)[0]
    return out if out_dtype is None else out.astype(out_dtype)

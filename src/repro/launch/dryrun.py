import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run driver.

For every (architecture x input-shape) cell this lowers + compiles the real
train/prefill/serve step against the production mesh with ShapeDtypeStruct
inputs (zero allocation), then records:

    * memory_analysis()  — proof the program fits per device;
    * cost_analysis()    — HLO flops / bytes for the roofline;
    * collective bytes   — parsed from the optimized HLO (see roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
        --out results/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro import configs
from repro.launch import roofline, sharding, shapes as SH
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import model as MD
from repro.optim import adamw, warmup_cosine


def _abstract_opt_state(opt, abstract_params):
    return jax.eval_shape(opt.init, abstract_params)


def lower_cell(arch: str, shape_name: str, mesh, cfg_overrides=None):
    """Returns (lowered, in_shardings_info) for one cell."""
    cfg = configs.get(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SH.SHAPES[shape_name]
    reason = SH.skip_reason(cfg, shape)
    if reason:
        return None, reason
    ac = sharding.make_ac(mesh, cfg)
    aparams = MD.abstract_params(cfg)
    pshard = sharding.param_shardings(cfg, aparams, mesh)
    ispec = SH.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = adamw(warmup_cosine(3e-4, 100, 10000), weight_decay=0.1)
        aopt = _abstract_opt_state(opt, aparams)
        # optimizer state inherits param shardings (ZeRO); step replicated
        import os as _os
        oshard = _opt_shardings(aopt, pshard, mesh,
                                zero1=bool(_os.environ.get("REPRO_ZERO1")))
        step = make_train_step(cfg, opt, ac)
        bshard = sharding.batch_shardings(ispec, mesh, pure_dp=cfg.pure_dp)
        jitted = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        with mesh:
            lowered = jitted.lower(aparams, aopt, ispec)
        return lowered, None

    if shape.kind == "prefill":
        step = make_prefill_step(cfg, ac)
        bshard = sharding.batch_shardings(ispec, mesh)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        with mesh:
            lowered = jitted.lower(aparams, ispec)
        return lowered, None

    # decode
    step = make_serve_step(cfg, ac)
    cshard = sharding.cache_shardings(ispec["cache"], mesh)
    tshard = sharding.batch_shardings({"tokens": ispec["tokens"]}, mesh)["tokens"]
    jitted = jax.jit(step,
                     in_shardings=(pshard, cshard, tshard, None),
                     out_shardings=(None, None, cshard),
                     donate_argnums=(1,))
    with mesh:
        lowered = jitted.lower(aparams, ispec["cache"], ispec["tokens"],
                               ispec["position"])
    return lowered, None


def _opt_shardings(aopt, pshard, mesh, zero1: bool = False):
    """AdamW state: mu/nu shaped like params -> same shardings (ZeRO falls
    out of param sharding); with ``zero1`` the moments are instead fully
    sharded over every mesh axis on their largest divisible dim (ZeRO-1:
    replicated params + sharded optimizer state)."""
    import numpy as _np
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    if not zero1:
        return type(aopt)(step=rep, mu=pshard, nu=pshard)
    axes = tuple(mesh.axis_names)
    n = int(_np.prod([mesh.shape[a] for a in axes]))

    def shard_state(leaf_shard, leaf):
        spec = [None] * len(leaf.shape)
        for i in sorted(range(len(leaf.shape)), key=lambda i: -leaf.shape[i]):
            if leaf.shape[i] % n == 0 and n > 1:
                spec[i] = axes
                break
        return NamedSharding(mesh, P(*spec))

    mu = jax.tree.map(shard_state, pshard, aopt.mu)
    return type(aopt)(step=rep, mu=mu, nu=mu)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             cfg_overrides=None, compute_roofline: bool = True,
             mesh_shape=None):
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    lowered, reason = lower_cell(arch, shape_name, mesh, cfg_overrides)
    if lowered is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": reason}
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    from repro import compat
    mem = compiled.memory_analysis()
    cost = compat.cost_analysis_dict(compiled)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_devices": len(mesh.devices.ravel()),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")
                 if cost and k in cost} if isinstance(cost, dict) else {},
    }
    if compute_roofline:
        rec["collectives"] = roofline.collective_bytes_from_hlo(
            compiled.as_text())
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SH.SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="results/dryrun")
    ap.add_argument("--moe-impl", type=str, default=None)
    ap.add_argument("--remat", type=str, default=None)
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--param-dtype", type=str, default=None)
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="override logical mesh, e.g. 64,4")
    ap.add_argument("--grad-accum", type=int, default=None)
    args = ap.parse_args()

    archs = configs.ARCHS if (args.all or args.arch is None) else [args.arch]
    shps = list(SH.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    if args.moe_impl:
        overrides["moe"] = None  # placeholder; applied per-config below
    if args.remat:
        overrides["remat_policy"] = args.remat
    if args.pure_dp:
        overrides["pure_dp"] = True
    if args.param_dtype:
        overrides["param_dtype"] = args.param_dtype
    if args.grad_accum is not None:
        overrides["grad_accum"] = args.grad_accum

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shps:
            for mp in meshes:
                tag = f"{configs.canonical(arch)}-{shape}-{'multi' if mp else 'single'}"
                out_path = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_path):
                    print(f"[skip existing] {tag}")
                    continue
                try:
                    ov = dict(overrides)
                    ov.pop("moe", None)
                    if args.moe_impl:
                        cfg0 = configs.get(arch)
                        if cfg0.moe is not None:
                            ov["moe"] = dataclasses.replace(
                                cfg0.moe, impl=args.moe_impl)
                    ms = (tuple(int(x) for x in args.mesh_shape.split(","))
                          if args.mesh_shape else None)
                    rec = run_cell(arch, shape, mp, ov or None, mesh_shape=ms)
                except Exception as e:  # noqa: BLE001 — record the failure
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[{rec['status']:7s}] {tag} "
                      + (f"compile={rec.get('compile_s')}s" if rec["status"] == "ok"
                         else rec.get("reason", rec.get("error", ""))[:120]))


if __name__ == "__main__":
    main()

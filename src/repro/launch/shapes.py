"""Assigned input-shape sets and abstract input specs (ShapeDtypeStruct —
no allocation; the dry-run pattern).

LM shapes (per the assignment):
    train_4k     seq 4096,    global_batch 256   (training)
    prefill_32k  seq 32768,   global_batch 32    (inference prefill)
    decode_32k   seq 32768,   global_batch 128   (decode: 1 new token, KV=32k)
    long_500k    seq 524288,  global_batch 1     (long-context decode;
                 SSM/hybrid only — quadratic-attention archs skip, see
                 DESIGN.md §Arch-applicability)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.uses_ssm:
        return ("pure full-attention arch: 500k-token decode requires "
                "sub-quadratic attention (run for SSM/hybrid only)")
    return None


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        specs = {}
        s_text = S
        if cfg.vision_tokens:
            s_text = S - cfg.vision_tokens
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
            if cfg.rope == "mrope":
                specs["positions"] = jax.ShapeDtypeStruct((3, B, S), i32)
        if cfg.n_codebooks > 1:
            specs["tokens"] = jax.ShapeDtypeStruct((B, cfg.n_codebooks, s_text), i32)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        return specs
    # decode: one new token against a cache of length S
    tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,)
    return {
        "tokens": jax.ShapeDtypeStruct(tok_shape, i32),
        "position": jax.ShapeDtypeStruct((B,), i32),
        "cache": MD.cache_shapes(cfg, B, S),
    }

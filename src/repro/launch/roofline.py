"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Hardware model: TPU v5e —
    peak bf16 compute : 197 TFLOP/s per chip
    HBM bandwidth     : 819 GB/s per chip
    ICI link bandwidth: ~50 GB/s per link

Terms (seconds, per step, per chip):
    compute    = HLO_flops        / (chips * PEAK_FLOPS)
    memory     = HLO_bytes        / (chips * HBM_BW)
    collective = collective_bytes / (chips * ICI_BW)

HLO flops/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
*not* in cost_analysis: we parse the optimized HLO text and sum operand sizes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops.  Two accounting subtleties, both handled here:

1.  **While loops** (scan over layers / chunks): XLA prints the loop body
    once.  We attribute ops to their enclosing computation and multiply by
    the loop trip count, which XLA exposes in the backend config / induction
    bounds when known; when not recoverable we fall back to the documented
    per-cell trip counts supplied by the caller (n_layers etc.).
2.  **Algorithmic bytes**: an all-reduce moves 2(n-1)/n x bytes, all-gather /
    reduce-scatter (n-1)/n x, with n the replica-group size parsed from the
    op.  We report algorithmic bytes on the busiest link class.
"""
from __future__ import annotations

import json
import os
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _algo_factor(op: str, n: int) -> float:
    if n <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op in ("all-gather", "reduce-scatter"):
        return (n - 1) / n
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0  # collective-permute: one hop


def collective_bytes_from_hlo(hlo_text: str, n_devices: int = 512,
                              loop_multiplier_fn=None) -> Dict:
    """Parse per-op collective bytes.  Ops inside while-loop bodies are
    counted once here; callers that know trip counts scale via
    ``loop_multiplier_fn(computation_name) -> int``."""
    per_op: Dict[str, float] = {}
    count: Dict[str, int] = {}
    current_comp = ""
    comp_re = re.compile(r"^\s*%?([\w.\-]+)\s+\([^)]*\)\s*->")
    body_bytes: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        mc = comp_re.match(line)
        if mc:
            current_comp = mc.group(1)
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        raw = _shape_bytes(dtype, dims)
        n = _group_size(line, n_devices)
        eff = raw * _algo_factor(op, n)
        mult = 1
        if loop_multiplier_fn is not None:
            mult = loop_multiplier_fn(current_comp)
        per_op[op] = per_op.get(op, 0.0) + eff * mult
        count[op] = count.get(op, 0) + 1
        body_bytes[current_comp] = body_bytes.get(current_comp, 0.0) + eff
    return {"per_op_bytes": per_op, "op_counts": count,
            "per_computation_bytes": body_bytes,
            "total_bytes": sum(per_op.values())}


# --------------------------------------------------------------------------- #
# Roofline terms
# --------------------------------------------------------------------------- #
def terms(flops: float, bytes_hbm: float, coll_bytes: float,
          n_chips: int) -> Dict:
    t_c = flops / (n_chips * PEAK_FLOPS)
    t_m = bytes_hbm / (n_chips * HBM_BW)
    t_x = coll_bytes / (n_chips * ICI_BW)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bound": dom[0], "step_s": dom[1],
            "roofline_fraction": (t_c / dom[1]) if dom[1] > 0 else 0.0}


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N per decoded
    token; D = tokens per step."""
    n_active = cfg.n_params(active_only=True)
    if kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch     # decode: 1 token/seq


def summarize(rec: dict, cfg, shape) -> dict:
    """Combine a dry-run record into the roofline row."""
    n = rec.get("n_devices", 512)
    flops = rec.get("cost", {}).get("flops") or 0.0
    bts = rec.get("cost", {}).get("bytes accessed") or 0.0
    coll = rec.get("collectives", {}).get("total_bytes", 0.0)
    # cost_analysis is per-program = per-device under SPMD
    t = terms(flops * n, bts * n, coll * n, n)
    mf = model_flops(cfg, shape, SHAPE_KIND[shape.name])
    t["model_flops"] = mf
    t["hlo_flops_total"] = flops * n
    t["useful_fraction"] = mf / max(flops * n, 1.0)
    t["mfu_at_roofline"] = mf / (n * PEAK_FLOPS * max(t["step_s"], 1e-12))
    return t


SHAPE_KIND = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def load_results(out_dir: str):
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            rows.append(json.load(open(os.path.join(out_dir, fn))))
    return rows

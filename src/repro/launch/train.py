"""End-to-end training driver.

Production machinery on any scale: pjit-sharded train step, deterministic
sharded data pipeline, atomic async checkpointing with auto-resume, gradient
clipping, (optional) 1-bit error-feedback gradient compression for the DP
axis, and supervisor-based crash restart.

The CT side plugs into the same mesh machinery:
:func:`make_ct_dp_train_step` builds a data-parallel
projector-in-the-loop step (the paper's differentiable projector inside
the loss, gradients pmean'd over the data axis) for training recon
networks against sinogram consistency.  It is the minimal DP primitive;
the full CT training subsystem — supervised + DC losses, EMA, checkpoint
/resume, eval harness, the same shard_map schedule behind
``data_parallel=True`` — is :mod:`repro.launch.ct_train`.

Examples:
    # smoke-train an assigned arch (reduced config) on CPU
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 30 --batch 8 --seq 128 --ckpt-dir /tmp/ck

    # resume is automatic: re-running picks up from the latest checkpoint
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro import compat, configs
from repro.data.tokens import TokenPipeline
from repro.launch import sharding
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import model as MD
from repro.optim import adamw, warmup_cosine
from repro.runtime import checkpoint as CKPT
from repro.runtime import compression
from repro.runtime.fault import Supervisor


def make_ct_dp_train_step(spec, mesh, apply_fn, lr: float = 1e-3,
                          axis: str = "data"):
    """Data-parallel projector-in-the-loop CT train step on ``mesh``.

    ``apply_fn(params, y) -> volume(s)`` is the recon network;  the loss is
    the projection-consistency term ``0.5 * mean (A x - y)^2`` with the
    paper's differentiable forward projector inside the graph, so gradients
    flow through the matched pair.  Each device runs the full projector on
    its batch shard (classic DP — the projector itself stays local; use
    :class:`~repro.core.distributed.DistributedProjector` when the *volume*
    outgrows a device instead), then grads and loss are pmean'd over
    ``axis``.  Returns a jitted ``step(params, y) -> (params, loss)`` with
    params replicated and ``y`` batch-sharded over ``axis``.
    """
    from repro.core.projector import Projector
    if getattr(spec, "shard", None) is not None:
        spec = spec.replace(shard=None)
    proj = Projector(spec)

    def _step(params, y):
        def loss_fn(p):
            x = apply_fn(p, y)
            return proj.data_consistency(x, y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads = jax.lax.pmean(grads, axis)
        loss = jax.lax.pmean(loss, axis)
        params = jax.tree_util.tree_map(lambda p, g: p - lr * g,
                                        params, grads)
        return params, loss

    stepped = compat.shard_map(_step, mesh, in_specs=(P(), P(axis)),
                               out_specs=(P(), P()), check_vma=False)
    return jax.jit(stepped)


def build(cfg, mesh, lr=3e-4, total_steps=10_000, compress=False):
    opt = adamw(warmup_cosine(lr, min(100, total_steps // 10 + 1), total_steps),
                weight_decay=0.1)
    ac = sharding.make_ac(mesh, cfg)
    comp_state = {"res": None}

    compress_fn = None
    if compress:
        def compress_fn(grads):
            q, comp_state["res"] = compression.compress(grads, comp_state["res"])
            return q

    step_fn = make_train_step(cfg, opt, ac, compress_fn=compress_fn)
    return opt, step_fn, ac


def train_loop(cfg, mesh, pipeline, steps: int, ckpt_dir: str = None,
               ckpt_every: int = 20, log_every: int = 5, seed: int = 0,
               fail_at_step: int = None):
    opt, step_fn, ac = build(cfg, mesh)
    aparams = MD.abstract_params(cfg)
    pshard = sharding.param_shardings(cfg, aparams, mesh)
    with mesh:
        params = jax.jit(lambda k: MD.init_params(cfg, k),
                         out_shardings=pshard)(jax.random.PRNGKey(seed))
        opt_state = jax.jit(opt.init, out_shardings=None)(params)

    start = 0
    ckpt = CKPT.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        (params, opt_state), extra, start = CKPT.restore(
            ckpt_dir, (jax.device_get(params), jax.device_get(opt_state)))
        pipeline.load_state_dict(extra["data"])
        with mesh:
            params = jax.device_put(params, pshard)
            opt_state = jax.device_put(opt_state)
        print(f"[restore] resumed from step {start}")

    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    t0 = time.time()
    for i in range(start, steps):
        # Drive the pipeline by explicit step index: the prefetch iterator
        # may run ahead of the train step, so checkpointing its internal
        # counter would replay the wrong batch on resume (found by
        # tests/test_launch.py::test_train_loop_checkpoint_resume).
        toks = pipeline.batch(i)
        pipeline.step = i + 1
        if fail_at_step is not None and i == fail_at_step:
            raise RuntimeError("injected failure (fault-tolerance test)")
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros(
                (toks.shape[0], cfg.vision_tokens, cfg.d_model),
                jnp.dtype(cfg.compute_dtype))
        with mesh:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if i % log_every == 0:
            print(f"step {i:5d}  loss {losses[-1]:.4f}  "
                  f"({(time.time()-t0)/max(i-start+1,1):.2f}s/step)")
        if ckpt and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, (params, opt_state),
                      {"data": pipeline.state_dict()})
    if ckpt:
        ckpt.save(steps, (params, opt_state), {"data": pipeline.state_dict()})
        ckpt.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_local_mesh())
    pipe = TokenPipeline(cfg.vocab_size, args.seq, args.batch)
    if cfg.n_codebooks > 1:
        base = pipe.batch
        pipe.batch = lambda step=None: np.stack(
            [base(step)] * cfg.n_codebooks, axis=1)

    attempts = {"n": 0}

    def loop(start):
        attempts["n"] += 1
        # inject the failure only on the first attempt (simulated node loss)
        fail = args.fail_at if attempts["n"] == 1 else None
        train_loop(cfg, mesh, pipe, args.steps, args.ckpt_dir,
                   ckpt_every=args.ckpt_every, fail_at_step=fail)
        return args.steps

    def restore():
        if args.ckpt_dir:
            return CKPT.latest_step(args.ckpt_dir) or 0
        return 0

    Supervisor(loop, restore, max_restarts=args.max_restarts).run()
    print("done.")


if __name__ == "__main__":
    main()

"""Analytic per-cell FLOP / HBM-byte / collective-byte model, cross-validated
against the compiled dry-run artifact.

Why analytic + HLO instead of HLO alone: ``compiled.cost_analysis()`` counts
each while-loop body ONCE (verified empirically: a 28-layer scanned model
reports ~= embed/head + one layer of flops).  Our programs are built from
loops with *known* trip counts (layer scan = n_layers, flash q/kv chunk loops
= S/chunk, SSM chunk scan = S/chunk), so we (a) compute the full-step numbers
analytically from the architecture and (b) validate the model by
reconstructing what cost_analysis *should* report with every loop counted
once and comparing.  EXPERIMENTS.md reports both and the validation residual.

All numbers are global (whole step, all chips); divide by chips for
per-device.  dtypes: compute bf16(2B), params/optimizer fp32(4B).
"""
from __future__ import annotations

from typing import Dict

from repro.launch.shapes import ShapeSpec
from repro.models.config import ModelConfig

BF16 = 2
F32 = 4


# --------------------------------------------------------------------------- #
# FLOPs
# --------------------------------------------------------------------------- #
def _attn_flops_per_layer(cfg: ModelConfig, B: int, S: int, causal_waste: bool):
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    d = cfg.d_model
    proj = 2 * B * S * d * (H * hd + 2 * KV * hd) + 2 * B * S * H * hd * d
    # scores+pv: full S^2 when the chunked path computes masked blocks too
    pairs = S * S if causal_waste else S * (S + 1) // 2
    sdpa = 2 * 2 * B * H * hd * pairs
    return proj + sdpa


def _mlp_flops_per_layer(cfg: ModelConfig, B: int, S: int):
    if cfg.family == "moe":
        m = cfg.moe
        mult = 3 if cfg.mlp == "swiglu" else 2
        router = 2 * B * S * cfg.d_model * m.n_experts
        eff_experts = {"dense": m.n_experts,
                       "ragged": m.top_k,
                       "gather": m.top_k * 1.25}[m.impl]  # capacity factor
        return router + mult * 2 * B * S * cfg.d_model * m.expert_d_ff * eff_experts
    if cfg.mlp == "none" or cfg.d_ff == 0:
        return 0
    mult = 3 if cfg.mlp == "swiglu" else 2
    return mult * 2 * B * S * cfg.d_model * cfg.d_ff


def _ssm_flops_per_layer(cfg: ModelConfig, B: int, S: int):
    d, di = cfg.d_model, cfg.d_inner
    N = cfg.ssm.d_state
    R = cfg.ssm.resolved_dt_rank(d)
    proj = 2 * B * S * (d * 2 * di + di * (R + 2 * N) + R * di + di * d)
    conv = 2 * B * S * di * cfg.ssm.d_conv
    # associative scan: ~2 passes of the combine over (di*N) per token,
    # each combine = 3 mul/add on (a,b) pairs
    scan = 2 * 3 * 2 * B * S * di * N
    gate = 4 * B * S * di
    return proj + conv + scan + gate


def flops_per_layer_fwd(cfg: ModelConfig, B: int, S: int,
                        causal_waste: bool = True) -> float:
    f = 0.0
    if cfg.uses_attention and cfg.family != "ssm":
        # sliding-window layers still compute full blocks in the jnp path
        f += _attn_flops_per_layer(cfg, B, S, causal_waste)
    if cfg.uses_ssm:
        f += _ssm_flops_per_layer(cfg, B, S)
    f += _mlp_flops_per_layer(cfg, B, S)
    return f


def embed_head_flops(cfg: ModelConfig, B: int, S: int, train: bool) -> float:
    # embedding lookup ~ free; logits matmul dominates
    lg = 2 * B * S * cfg.d_model * cfg.vocab_size * cfg.n_codebooks
    return lg


def train_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    fwd = cfg.n_layers * flops_per_layer_fwd(cfg, B, S)
    # backward = 2x fwd; full remat recomputes fwd once more
    remat = {"none": 0.0, "dots": 0.5, "full": 1.0}[cfg.remat_policy]
    body = fwd * (3.0 + remat)
    head = embed_head_flops(cfg, B, S, True) * 3.0
    return body + head


def prefill_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    return (cfg.n_layers * flops_per_layer_fwd(cfg, B, S)
            + 2 * B * cfg.d_model * cfg.vocab_size * cfg.n_codebooks)


def decode_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    f = 0.0
    hd = cfg.resolved_head_dim
    for _ in range(1):
        if cfg.uses_attention and cfg.family != "ssm":
            d = cfg.d_model
            H, KV = cfg.n_heads, cfg.n_kv_heads
            proj = 2 * B * d * (H * hd + 2 * KV * hd) + 2 * B * H * hd * d
            ctx = S if cfg.sliding_window is None else (
                S if cfg.global_attn_every > 0 else min(S, cfg.sliding_window))
            sdpa = 2 * 2 * B * H * hd * ctx
            f += proj + sdpa
        if cfg.uses_ssm:
            d, di = cfg.d_model, cfg.d_inner
            N = cfg.ssm.d_state
            R = cfg.ssm.resolved_dt_rank(d)
            f += 2 * B * (d * 2 * di + di * (R + 2 * N) + R * di + di * d) \
                + 2 * B * di * cfg.ssm.d_conv + 6 * B * di * N
        f += _mlp_flops_per_layer(cfg, B, 1)
    f *= cfg.n_layers
    f += 2 * B * cfg.d_model * cfg.vocab_size * cfg.n_codebooks
    return f


# --------------------------------------------------------------------------- #
# HBM bytes (global)
# --------------------------------------------------------------------------- #
def train_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    T = B * S
    P = cfg.n_params()
    d = cfg.d_model
    # params fp32: read fwd + read bwd + read remat; grads write+read;
    # adam mu/nu read+write; param write
    param_traffic = P * F32 * (3 + 2 + 4 + 1)
    # residual stream: with full remat only layer inputs are saved:
    # write fwd + read bwd per layer, bf16
    act_traffic = cfg.n_layers * T * d * BF16 * 2
    # per-layer working set (inputs/outputs of the big matmuls), fused
    # conservatively as 4 x residual reads/writes fwd + 8 x bwd(+remat)
    act_traffic += cfg.n_layers * T * d * BF16 * 12
    # logits fp32 write+read
    logits = 2 * T * cfg.vocab_size * cfg.n_codebooks * F32 / max(
        1, 1)  # sharded over model axis but global bytes unchanged
    return param_traffic + act_traffic + logits


def prefill_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    T = B * S
    P = cfg.n_params()
    return P * F32 + cfg.n_layers * T * cfg.d_model * BF16 * 8 \
        + 2 * B * cfg.vocab_size * cfg.n_codebooks * F32


def decode_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    B, S = shape.global_batch, shape.seq_len
    P_active = cfg.n_params(active_only=True)   # MoE ragged reads top-k experts
    if cfg.moe and cfg.moe.impl == "dense":
        P_active = cfg.n_params()
    bts = P_active * F32                        # every weight read per token
    hd = cfg.resolved_head_dim
    if cfg.uses_attention and cfg.family != "ssm":
        ctx = S if (cfg.sliding_window is None or cfg.global_attn_every > 0) \
            else min(S, cfg.sliding_window)
        bts += cfg.n_layers * B * ctx * cfg.n_kv_heads * hd * 2 * BF16  # read K,V
    if cfg.uses_ssm:
        bts += cfg.n_layers * B * cfg.d_inner * cfg.ssm.d_state * F32 * 2
    return bts


# --------------------------------------------------------------------------- #
# Collective bytes (global, analytic; cross-checked vs HLO parse)
# --------------------------------------------------------------------------- #
def train_collective_bytes(cfg: ModelConfig, shape: ShapeSpec,
                           tp: int, dp: int, fsdp: bool) -> float:
    """SUM over devices of bytes crossing each device's links (so that
    dividing by n_chips in roofline.terms gives per-device link time —
    collectives do NOT parallelize across chips the way flops do).

    Per TP activation all-reduce: each of the dp TP-groups all-reduces its
    (T/dp, d) activation; per-device bytes = (T/dp)*d*B*2(tp-1)/tp, and the
    sum over all tp*dp devices is  tp * T * d * B * 2(tp-1)/tp."""
    B, S = shape.global_batch, shape.seq_len
    T = B * S
    d = cfg.d_model
    P = cfg.n_params()
    coll = 0.0
    if tp > 1 and cfg.grad_accum >= 0:
        ar = 2.0 * (tp - 1) / tp
        n_ar = 4 if (cfg.uses_attention and cfg.mlp != "none") else 2
        coll += cfg.n_layers * n_ar * tp * T * d * BF16 * ar
    if dp > 1:
        pbytes = P * (F32 if cfg.param_dtype == "float32" else BF16)
        if fsdp:
            # fwd param all-gather + bwd all-gather + grad reduce-scatter:
            # per-device 3*(P/tp)*(dp-1)/dp; summed over tp*dp devices:
            coll += 3.0 * pbytes * (dp - 1)
        else:
            # gradient all-reduce: per-device (P/tp)*2(dp-1)/dp; summed:
            coll += 2.0 * pbytes * (dp - 1)
    return coll


def decode_collective_bytes(cfg: ModelConfig, shape: ShapeSpec,
                            tp: int, dp: int) -> float:
    B = shape.global_batch
    d = cfg.d_model
    coll = 0.0
    if tp > 1:
        ar = 2.0 * (tp - 1) / tp
        n_ar = 2 if (cfg.uses_attention and cfg.mlp != "none") else 1
        # activations replicated/batch-sharded over dp; per TP-group tensor
        # is (B/dp, d): sum over devices = tp * B * d * ...
        coll += cfg.n_layers * n_ar * tp * B * d * BF16 * ar
        coll += tp * B * cfg.vocab_size * cfg.n_codebooks * BF16 * ar
    return coll


# --------------------------------------------------------------------------- #
def analytic_cell(cfg: ModelConfig, shape: ShapeSpec, tp: int, dp: int,
                  fsdp: bool = None) -> Dict:
    if fsdp is None:
        fsdp = cfg.n_params() > 3e9
    kind = {"train_4k": "train", "prefill_32k": "prefill"}.get(
        shape.name, "decode")
    if kind == "train":
        fl, bts = train_flops(cfg, shape), train_bytes(cfg, shape)
        coll = train_collective_bytes(cfg, shape, tp, dp, fsdp)
    elif kind == "prefill":
        fl, bts = prefill_flops(cfg, shape), prefill_bytes(cfg, shape)
        coll = train_collective_bytes(cfg, shape, tp, dp, False) / 3.0
    else:
        fl, bts = decode_flops(cfg, shape), decode_bytes(cfg, shape)
        coll = decode_collective_bytes(cfg, shape, tp, dp)
    return {"flops": fl, "hbm_bytes": bts, "collective_bytes": coll,
            "kind": kind}


def hlo_counted_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """What cost_analysis is expected to report (every while-loop body
    counted ONCE) — used to validate the analytic model against the
    artifact.  Loops in our programs: grad-accum microbatch scan, layer
    scan, flash q/kv chunk loops, SSM chunk scan (the associative scan
    *within* a chunk is unrolled log-depth ops and is fully counted).

    Validation is meaningful for train/prefill (matmul-dominated); decode
    programs are sub-millisecond and dominated by non-matmul ops that the
    analytic model ignores, so decode ratios >1 are expected."""
    kind = {"train_4k": "train", "prefill_32k": "prefill"}.get(
        shape.name, "decode")
    B, S = shape.global_batch, shape.seq_len
    if kind == "train":
        B = B // max(cfg.grad_accum, 1)   # microbatch loop counted once
    if kind == "decode":
        return decode_flops(cfg, shape) / cfg.n_layers \
            + 2 * B * cfg.d_model * cfg.vocab_size * cfg.n_codebooks
    # one layer, with inner seq chunk loops also counted once
    one_layer = flops_per_layer_fwd(cfg, B, S)
    if cfg.uses_attention and S > 2048 and cfg.family != "ssm":
        # flash: lax.map over q-chunks counted once, inner kv scan once
        hd = cfg.resolved_head_dim
        full_sdpa = 2 * 2 * B * cfg.n_heads * hd * S * S
        cq = min(1024, S)
        ck = min(1024, S)
        one_layer -= full_sdpa * (1.0 - (cq * ck) / (S * S))
    if cfg.uses_ssm:
        # only the chunked scan body is inside a while loop; the projections
        # and conv are full-sequence ops outside it
        chunk = min(512, S)
        scan_part = 2 * 3 * 2 * B * S * cfg.d_inner * cfg.ssm.d_state
        one_layer -= scan_part * (1.0 - chunk / S)
    mult = {"train": 3.0 + {"none": 0, "dots": 0.5, "full": 1.0}[
        cfg.remat_policy], "prefill": 1.0}[kind]
    return one_layer * mult + embed_head_flops(cfg, B, S, kind == "train") \
        * (3.0 if kind == "train" else 1.0 / S)

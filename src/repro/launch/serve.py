"""Batched serving driver: continuous-batching decode loop over any arch.

Production posture on CPU scale: a slot-based scheduler keeps a fixed-shape
decode batch full (JAX/XLA needs static shapes — finished sequences free
their slot for the next queued request), greedy or temperature sampling,
per-request max-token / EOS stopping, and step-time telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --batch-slots 4 --max-new 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import sharding
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models import model as MD


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Fixed-slot continuous batching.  Each slot holds one request; the
    KV/SSM cache is (slots, ...) and slots are recycled as requests finish.
    Prompts are prefilling token-by-token through the decode step (simple
    and correct; the chunked-prefill path is the `make_prefill_step`
    program used by the dry-run)."""

    def __init__(self, cfg, mesh=None, slots: int = 4, max_len: int = 256,
                 eos_id: Optional[int] = None, seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh or make_local_mesh()
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        ac = sharding.make_ac(self.mesh, cfg)
        self._step = jax.jit(make_serve_step(cfg, ac))
        self.params = MD.init_params(cfg, jax.random.PRNGKey(seed))
        self.cache = MD.init_cache(cfg, slots, max_len)
        self.positions = np.zeros(slots, np.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.steps = 0

    def load_params(self, params):
        self.params = params

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self.positions[s] = 0
                # reset this slot's cache lanes
                self.cache = jax.tree.map(
                    lambda c: c.at[:, s].set(0.0) if c.ndim >= 2 else c,
                    self.cache)

    def _slot_token(self, s: int) -> int:
        req = self.active[s]
        if req is None:
            return 0
        pos = int(self.positions[s])
        if pos < len(req.prompt):
            return req.prompt[pos]
        if req.out:
            return req.out[-1]
        return req.prompt[-1]

    def step(self):
        """One synchronous decode step across all slots."""
        self._admit()
        if not any(self.active):
            return False
        toks = jnp.asarray([self._slot_token(s) for s in range(self.slots)],
                           jnp.int32)
        if self.cfg.n_codebooks > 1:
            toks = jnp.tile(toks[:, None], (1, self.cfg.n_codebooks))
        pos = jnp.asarray(self.positions, jnp.int32)   # per-slot depths
        with self.mesh:
            nxt, logits, self.cache = self._step(self.params, self.cache,
                                                 toks, pos)
        nxt = np.asarray(nxt)
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            self.positions[s] += 1
            pos_s = int(self.positions[s])
            if pos_s >= len(req.prompt):       # generating
                tok = int(nxt[s, 0] if nxt.ndim > 1 else nxt[s])
                req.out.append(tok)
                if (len(req.out) >= req.max_new
                        or (self.eos_id is not None and tok == self.eos_id)
                        or pos_s >= self.max_len - 1):
                    req.done = True
                    self.active[s] = None
        self.steps += 1
        return True

    def run(self) -> List[Request]:
        finished: List[Request] = []
        seen = set()
        pending = list(self.queue)
        t0 = time.time()
        while self.step():
            pass
        dt = time.time() - t0
        for r in pending:
            if r.done and r.rid not in seen:
                finished.append(r)
                seen.add(r.rid)
        if self.steps:
            print(f"[serve] {self.steps} steps, "
                  f"{dt / max(self.steps, 1) * 1e3:.1f} ms/step, "
                  f"{len(finished)} requests")
        return finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    srv = Server(cfg, mesh, slots=args.batch_slots, max_len=128)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(3, 10)).tolist()
        srv.submit(Request(rid, prompt, args.max_new))
    done = srv.run()
    for r in done[:4]:
        print(f"req {r.rid}: prompt[{len(r.prompt)}] -> {r.out}")


if __name__ == "__main__":
    main()

"""Projector-in-the-loop CT training subsystem (the flagship trained-model
path).

The paper's entire point is the differentiable FP/BP pair *inside* deep
learning pipelines; this module is the subsystem that actually trains recon
networks through it, across the three hard geometry classes:

  * ``limited_angle``  — parallel beam, a contiguous missing angular wedge
                         (paper §4; hybrid CT-Net + U-Net supported);
  * ``sparse_fan``     — fan beam, randomly decimated views (sparse-view CT);
  * ``helical``        — modular-frame helical trajectory over a 3D volume,
                         sparse views along the helix.

One :class:`TrainConfig` (frozen, validated) describes a run; one
:class:`CTTrainer` executes it:

    cfg = TrainConfig(geometry="sparse_fan", n=48, steps=300)
    trainer = CTTrainer(cfg)
    losses = trainer.fit()             # auto-resumes from cfg.ckpt_dir
    metrics = trainer.evaluate()       # PSNR/SSIM + DC residual, EMA params

Training loss = supervised reconstruction MSE + the paper's masked
data-consistency term through the matched projector pair (+ a sinogram-
completion term for the hybrid model).  Evaluation runs the full paper-§4
inference pipeline (network prediction, then CG data-consistency
refinement) and reports both image quality (PSNR/SSIM) and the relative
projection-consistency residual per geometry — the same numbers the
``fig3_data_consistency`` benchmark feeds to the CI quality gate.

Scale-out: ``data_parallel=True`` runs the train step under
``compat.shard_map`` over the local mesh's data axis — params/opt/EMA
replicated, the batch sharded, grads+loss pmean'd — the same classic-DP
schedule as :func:`repro.launch.train.make_ct_dp_train_step` (the projector
stays local per shard; a spec carrying a
:class:`~repro.core.spec.ShardSpec` is stripped the same way, because DP
and operator sharding compose through
:class:`~repro.core.distributed.DistributedProjector`, not through this
step).  ``compute_dtype`` threads the bf16-tile / f32-accumulate kernel
policy straight into the in-loop projector.

CLI (what the CI ``training-smoke`` job runs)::

    PYTHONPATH=src python -m repro.launch.ct_train \
        --geometry all --smoke --check --metrics-json metrics.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.geometry import (CTGeometry, VolumeGeometry, fan_beam,
                                 helical_beam, parallel_beam)
from repro.core.projector import Projector
from repro.core.spec import ProjectorSpec
from repro.data.metrics import psnr, ssim
from repro.data.pipeline import CTDataPipeline
from repro.launch.mesh import dp_size, make_local_mesh
from repro.nn.ctnet import ctnet_apply, ctnet_init
from repro.nn.unet import unet_apply, unet_init
from repro.optim import (adamw, apply_updates, ema_init, ema_params,
                         ema_update, warmup_cosine)
from repro.recon.completion import complete_and_refine, projection_residual
from repro.runtime import checkpoint as CKPT

__all__ = ["GEOMETRIES", "TrainConfig", "CTTrainer", "build_geometry",
           "smoke_config", "main"]

GEOMETRIES = ("limited_angle", "sparse_fan", "helical")
_MODELS = ("auto", "unet", "hybrid")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Frozen description of one projector-in-the-loop training run.

    Geometry/data:
        geometry:      one of :data:`GEOMETRIES`.
        n:             transaxial volume size (``n x n`` voxels).
        nz:            axial size; 0 = auto (8 for helical, 1 otherwise).
        available_deg: angular coverage for ``limited_angle`` masks.
        n_views_few:   measured views for the sparse modes; 0 = auto
                       (half of the geometry's views).
    Model:
        model:         "auto" | "unet" | "hybrid".  "auto" picks the paper's
                       hybrid CT-Net + U-Net for ``limited_angle`` and the
                       image-domain U-Net elsewhere; "hybrid" needs a 2D
                       (single detector row) geometry.
        base/levels:   U-Net width/depth;  ``depth`` is the CT-Net depth.
    Optimization:
        steps/batch/lr/warmup: the usual; AdamW + warmup-cosine.
        dc_weight:     weight of the masked data-consistency loss through
                       the projector (0 disables — ablation).
        sino_weight:   weight of the sinogram-completion loss (hybrid only).
        ema_decay/ema_warmup: eval-parameter averaging (see optim/ema.py).
    Infrastructure:
        compute_dtype: kernel tile precision for the in-loop projector
                       ("bfloat16" | "float32" | None = follow input).
        data_parallel: shard the batch over the local mesh's data axis.
        ckpt_dir/ckpt_every: checkpoint location and cadence (None = off).
        refine_iters/refine_beta: CG data-consistency refinement used by
                       :meth:`CTTrainer.evaluate`.
    """

    geometry: str = "limited_angle"
    n: int = 48
    nz: int = 0
    available_deg: float = 60.0
    n_views_few: int = 0
    model: str = "auto"
    base: int = 16
    levels: int = 2
    depth: int = 3
    steps: int = 120
    batch: int = 4
    lr: float = 2e-3
    warmup: int = 20
    dc_weight: float = 0.1
    sino_weight: float = 0.5
    ema_decay: float = 0.999
    ema_warmup: int = 10
    compute_dtype: Optional[str] = None
    data_parallel: bool = False
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    refine_iters: int = 20
    refine_beta: float = 0.05
    seed: int = 0

    def __post_init__(self):
        if self.geometry not in GEOMETRIES:
            raise ValueError(f"unknown geometry {self.geometry!r}; expected "
                             f"one of {GEOMETRIES}")
        if self.model not in _MODELS:
            raise ValueError(f"unknown model {self.model!r}; expected one "
                             f"of {_MODELS}")
        if self.n < 8:
            raise ValueError(f"n must be >= 8, got {self.n}")
        if self.nz == 0:
            object.__setattr__(self, "nz",
                               8 if self.geometry == "helical" else 1)
        if self.nz < 1:
            raise ValueError(f"nz must be >= 1 (or 0 = auto), got {self.nz}")
        if self.geometry == "helical" and self.nz < 2:
            raise ValueError("helical training needs a volumetric object "
                             f"(nz >= 2), got nz={self.nz}")
        if self.steps < 1 or self.batch < 1:
            raise ValueError(f"steps/batch must be >= 1, got "
                             f"{(self.steps, self.batch)}")
        if self.resolved_model == "hybrid" and self.geometry == "helical":
            raise ValueError("the hybrid CT-Net path operates on 2D "
                             "(single-row) sinograms; helical geometries "
                             "need model='unet'")
        if not 0.0 <= self.dc_weight:
            raise ValueError(f"dc_weight must be >= 0, got {self.dc_weight}")

    @property
    def resolved_model(self) -> str:
        if self.model != "auto":
            return self.model
        return "hybrid" if self.geometry == "limited_angle" else "unet"

    @property
    def mask_mode(self) -> str:
        return ("limited_angle" if self.geometry == "limited_angle"
                else "few_view")

    def replace(self, **kw) -> "TrainConfig":
        return dataclasses.replace(self, **kw)


def build_geometry(cfg: TrainConfig) -> CTGeometry:
    """The scanner for a config — one representative hard geometry per
    class, sized relative to ``cfg.n`` so every knob scales together."""
    n = cfg.n
    if cfg.geometry == "limited_angle":
        vol = VolumeGeometry(n, n, 1)
        return parallel_beam(int(1.5 * n), 1, int(1.5 * n), vol)
    if cfg.geometry == "sparse_fan":
        vol = VolumeGeometry(n, n, 1)
        return fan_beam(int(1.5 * n), 1, int(2.2 * n), vol,
                        sod=2.0 * n, sdd=3.0 * n, angular_range=360.0)
    # helical: 2 turns covering the volume's z extent, detector rows wide
    # enough (at magnification 1.5) to see the whole pitch per view.
    vol = VolumeGeometry(n, n, cfg.nz)
    return helical_beam(n_turns=2.0, pitch=cfg.nz / 2.0,
                        n_angles=int(1.5 * n), n_rows=max(6, cfg.nz // 2 + 2),
                        n_cols=int(2.2 * n), vol=vol,
                        sod=2.0 * n, sdd=3.0 * n, pixel_height=2.0)


def smoke_config(geometry: str, **overrides) -> TrainConfig:
    """Tiny CPU-trainable config (~40 steps) — what the CI ``training-smoke``
    job and the quality benchmark run."""
    base = dict(geometry=geometry, n=32, steps=40, batch=4, base=8,
                levels=2, depth=2, lr=2e-3, warmup=5, ema_warmup=5,
                refine_iters=15)
    if geometry == "helical":
        base.update(n=20, nz=4, batch=2)
    base.update(overrides)
    return TrainConfig(**base)


class CTTrainer:
    """Spec-first projector-in-the-loop trainer: ``fit`` / ``evaluate`` /
    ``resume`` (see module docstring)."""

    def __init__(self, cfg: TrainConfig):
        self.cfg = cfg
        self.geom = build_geometry(cfg)
        self.spec = ProjectorSpec(self.geom,
                                  compute_dtype=cfg.compute_dtype)
        self.proj = Projector(self.spec)
        n_few = cfg.n_views_few or max(8, self.geom.n_angles // 2)
        self.pipe = CTDataPipeline(self.geom, batch_size=cfg.batch,
                                   seed=cfg.seed, mode=cfg.mask_mode,
                                   available_deg=cfg.available_deg,
                                   n_views_few=n_few)
        key = jax.random.PRNGKey(cfg.seed)
        self.params = self._init_params(key)
        self.opt = adamw(warmup_cosine(cfg.lr, cfg.warmup, cfg.steps))
        self.opt_state = self.opt.init(self.params)
        self.ema = ema_init(self.params)
        self.step = 0
        self._step_fn = None
        self._mesh = None

    # -- model ------------------------------------------------------------- #
    def _init_params(self, key):
        cfg = self.cfg
        in_ch = cfg.nz
        unet = unet_init(jax.random.fold_in(key, 1), base=cfg.base,
                         levels=cfg.levels, in_ch=in_ch, out_ch=in_ch)
        if cfg.resolved_model == "hybrid":
            return {"ctnet": ctnet_init(key, base=cfg.base, depth=cfg.depth),
                    "unet": unet}
        return {"unet": unet}

    def _initial_recon(self, sino_masked, mask):
        """Network input from the ill-posed data: masked FBP where an
        analytic inverse exists (parallel/fan), mask-normalized
        backprojection for modular/helical frames (no analytic helical
        recon in the stack — ROADMAP)."""
        m4 = mask[:, :, None, None]
        if self.geom.geom_type in ("parallel", "fan"):
            return self.proj.fbp(sino_masked * m4)
        # SIRT-style normalization A^T(M y) / A^T(M A 1): the denominator
        # carries the ray path lengths, so x0 lands at attenuation scale
        # (a plain ray-count normalization overshoots by ~L, the chord
        # length through the volume).
        fp_ones = self.proj(jnp.ones(self.geom.vol.shape,
                                     sino_masked.dtype))
        norm = self.proj.T(m4 * fp_ones[None])
        x0 = self.proj.T(m4 * sino_masked)
        floor = 1e-3 * jnp.max(norm, axis=(1, 2, 3), keepdims=True) + 1e-12
        return x0 / jnp.maximum(norm, floor)

    def predict(self, params, sino_masked, mask):
        """(B, na, nv, nu) masked sinogram + (B, na) view mask ->
        ``(volume (B, nx, ny, nz), completed sinogram or None)``."""
        if self.cfg.resolved_model == "hybrid":
            mask2d = mask[:, :, None] * jnp.ones((1, 1, self.geom.n_cols),
                                                 sino_masked.dtype)
            completed = ctnet_apply(params["ctnet"], sino_masked[:, :, 0, :],
                                    mask2d)
            x_in = self.proj.fbp(completed[:, :, None, :])
            pred = unet_apply(params["unet"], x_in)
            return pred, completed[:, :, None, :]
        x_in = self._initial_recon(sino_masked, mask)
        return unet_apply(params["unet"], x_in), None

    # -- loss / step ------------------------------------------------------- #
    def loss_fn(self, params, sino, mask, gt_vol):
        """Supervised MSE + masked data-consistency through the matched
        pair (+ completion loss for the hybrid model)."""
        cfg = self.cfg
        m4 = mask[:, :, None, None]
        pred, completed = self.predict(params, sino * m4, mask)
        loss = jnp.mean(jnp.square(pred - gt_vol))
        if cfg.dc_weight:
            dc = jnp.mean(jnp.square((self.proj(pred) - sino) * m4))
            loss = loss + cfg.dc_weight * dc
        if completed is not None:
            loss = loss + cfg.sino_weight * jnp.mean(
                jnp.square(completed - sino))
        return loss

    def _make_step(self):
        def _step(params, opt_state, ema, sino, mask, gt_vol):
            loss, grads = jax.value_and_grad(self.loss_fn)(
                params, sino, mask, gt_vol)
            if self._mesh is not None:
                grads = jax.lax.pmean(grads, "data")
                loss = jax.lax.pmean(loss, "data")
            updates, opt_state = self.opt.update(grads, opt_state, params)
            params = apply_updates(params, updates)
            ema = ema_update(ema, params, decay=self.cfg.ema_decay,
                             warmup=self.cfg.ema_warmup)
            return params, opt_state, ema, loss

        if self._mesh is None:
            return jax.jit(_step)
        repl, shard = P(), P("data")
        return jax.jit(compat.shard_map(
            _step, self._mesh,
            in_specs=(repl, repl, repl, shard, shard, shard),
            out_specs=(repl, repl, repl, repl), check_vma=False))

    def _as_volume(self, imgs):
        a = jnp.asarray(imgs)
        return a if a.ndim == 4 else a[..., None]

    # -- public API -------------------------------------------------------- #
    def resume(self) -> int:
        """Restore params/opt/EMA + the data-pipeline cursor from the latest
        checkpoint under ``cfg.ckpt_dir``.  Returns the restored step (0
        when there is nothing to restore)."""
        cfg = self.cfg
        if not cfg.ckpt_dir or CKPT.latest_step(cfg.ckpt_dir) is None:
            return 0
        tree = (self.params, self.opt_state, self.ema)
        (self.params, self.opt_state, self.ema), extra, self.step = \
            CKPT.restore(cfg.ckpt_dir, tree)
        self.pipe.load_state_dict(extra["data"])
        return self.step

    def fit(self, log_every: int = 20, on_step=None):
        """Run the configured schedule (auto-resuming first); returns the
        per-step loss list.  ``on_step(i, loss)`` is an optional callback
        (progress reporting / benchmark timing)."""
        cfg = self.cfg
        start = self.resume()
        if self._step_fn is None:
            if cfg.data_parallel and jax.device_count() > 1:
                self._mesh = make_local_mesh()
                if cfg.batch % dp_size(self._mesh):
                    raise ValueError(
                        f"batch={cfg.batch} must divide over the "
                        f"{dp_size(self._mesh)}-way data axis")
            self._step_fn = self._make_step()
        ckpt = (CKPT.AsyncCheckpointer(cfg.ckpt_dir)
                if cfg.ckpt_dir else None)
        losses = []
        t0 = time.time()
        for i in range(start, cfg.steps):
            imgs, masks = self.pipe.batch(i)
            gt_vol = self._as_volume(imgs)
            sino = self.proj(gt_vol)
            self.params, self.opt_state, self.ema, loss = self._step_fn(
                self.params, self.opt_state, self.ema, sino,
                jnp.asarray(masks), gt_vol)
            loss = float(loss)
            losses.append(loss)
            self.step = i + 1
            if on_step is not None:
                on_step(i, loss)
            if log_every and i % log_every == 0:
                print(f"[{cfg.geometry}] step {i:4d}  loss {loss:.6f}  "
                      f"({(time.time() - t0) / max(i - start + 1, 1):.2f}"
                      f"s/step)")
            if ckpt and self.step % cfg.ckpt_every == 0:
                ckpt.save(self.step, (self.params, self.opt_state, self.ema),
                          {"data": self.pipe.state_dict()})
        if ckpt:
            ckpt.save(self.step, (self.params, self.opt_state, self.ema),
                      {"data": self.pipe.state_dict()})
            ckpt.wait()
        return losses

    def evaluate(self, n_test: int = 4, use_ema: bool = True,
                 params=None) -> dict:
        """Held-out phantoms through the full paper-§4 inference pipeline.

        Returns per-geometry quality numbers (means over ``n_test``):
        ``psnr_net``/``ssim_net`` for the raw network prediction,
        ``psnr_refined``/``ssim_refined`` after CG data-consistency
        refinement, and the relative projection residuals ``dc_net`` /
        ``dc_refined``.  Uses the EMA parameters by default — the weights a
        deployment would serve."""
        cfg = self.cfg
        if params is None:
            params = ema_params(self.ema) if use_ema else self.params
        acc = {k: 0.0 for k in ("psnr_net", "ssim_net", "psnr_refined",
                                "ssim_refined", "dc_net", "dc_refined")}
        for k in range(n_test):
            img, mask = self.pipe.sample(10_000 + k, 0)
            gt_vol = self._as_volume(img[None])[0]
            sino = self.proj(gt_vol)
            m3 = jnp.asarray(mask)[:, None, None]
            pred, _ = self.predict(params, (sino * m3)[None],
                                   jnp.asarray(mask)[None])
            pred = pred[0]
            xr, _ = complete_and_refine(self.proj, pred, sino, m3,
                                        n_iters=cfg.refine_iters,
                                        beta=cfg.refine_beta)
            gt_np, pred_np = np.asarray(gt_vol), np.asarray(pred)
            xr_np = np.asarray(xr)
            peak = float(gt_np.max())
            acc["psnr_net"] += psnr(pred_np, gt_np, peak)
            acc["ssim_net"] += ssim(pred_np, gt_np, peak)
            acc["psnr_refined"] += psnr(xr_np, gt_np, peak)
            acc["ssim_refined"] += ssim(xr_np, gt_np, peak)
            acc["dc_net"] += float(projection_residual(self.proj, pred,
                                                       sino, m3))
            acc["dc_refined"] += float(projection_residual(self.proj, xr,
                                                           sino, m3))
        return {k: v / n_test for k, v in acc.items()}


# --------------------------------------------------------------------------- #
# CLI — also the CI training-smoke entry point
# --------------------------------------------------------------------------- #
def _check_run(geometry: str, losses, metrics) -> list:
    """The training-smoke acceptance conditions; returns failure strings."""
    fails = []
    q = max(len(losses) // 4, 1)
    head, tail = float(np.mean(losses[:q])), float(np.mean(losses[-q:]))
    if not tail < head:
        fails.append(f"{geometry}: loss did not decrease "
                     f"(first-quarter mean {head:.6f} -> last-quarter "
                     f"mean {tail:.6f})")
    if not metrics["psnr_refined"] > metrics["psnr_net"]:
        fails.append(f"{geometry}: data-consistency refinement did not "
                     f"improve PSNR ({metrics['psnr_net']:.3f} dB -> "
                     f"{metrics['psnr_refined']:.3f} dB)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--geometry", default="all",
                    choices=GEOMETRIES + ("all",))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CPU-trainable config (CI training-smoke)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--size", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--model", default=None, choices=_MODELS)
    ap.add_argument("--dc-weight", type=float, default=None)
    ap.add_argument("--compute-dtype", default=None)
    ap.add_argument("--data-parallel", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--n-test", type=int, default=4)
    ap.add_argument("--metrics-json", default=None,
                    help="write per-geometry losses+metrics as JSON")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless loss decreases and DC refinement "
                         "improves PSNR on held-out phantoms (CI gate)")
    args = ap.parse_args(argv)

    overrides = {}
    for field, name in (("steps", "steps"), ("n", "size"),
                        ("batch", "batch"), ("model", "model"),
                        ("dc_weight", "dc_weight"),
                        ("compute_dtype", "compute_dtype")):
        v = getattr(args, name)
        if v is not None:
            overrides[field] = v
    if args.data_parallel:
        overrides["data_parallel"] = True

    geometries = GEOMETRIES if args.geometry == "all" else (args.geometry,)
    results, failures = {}, []
    for geometry in geometries:
        per_geom = dict(overrides)
        if args.ckpt_dir:
            per_geom["ckpt_dir"] = f"{args.ckpt_dir}/{geometry}"
        cfg = (smoke_config(geometry, **per_geom) if args.smoke
               else TrainConfig(geometry=geometry, **per_geom))
        print(f"=== {geometry}: {cfg.resolved_model} model, "
              f"{cfg.steps} steps, vol {build_geometry(cfg).vol.shape} ===")
        trainer = CTTrainer(cfg)
        t0 = time.time()
        losses = trainer.fit()
        train_s = time.time() - t0
        metrics = trainer.evaluate(n_test=args.n_test)
        print(f"    loss {losses[0]:.6f} -> {losses[-1]:.6f}   "
              f"net {metrics['psnr_net']:.3f} dB -> refined "
              f"{metrics['psnr_refined']:.3f} dB   "
              f"dc {metrics['dc_net']:.4f} -> {metrics['dc_refined']:.4f}")
        results[geometry] = {"config": dataclasses.asdict(cfg),
                             "losses": losses, "train_seconds": train_s,
                             "metrics": metrics}
        if args.check:
            failures.extend(_check_run(geometry, losses, metrics))

    if args.metrics_json:
        with open(args.metrics_json, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.metrics_json}")
    for f in failures:
        print(f"FAIL: {f}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

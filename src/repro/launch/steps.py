"""jit-able training / serving step builders (shared by the real trainer,
the smoke tests and the multi-pod dry-run)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.optim.adamw import Optimizer, apply_updates, clip_by_global_norm


def make_train_step(cfg: ModelConfig, opt: Optimizer, ac: Callable = None,
                    grad_accum: int = None, clip_norm: float = 1.0,
                    compress_fn: Callable = None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    grad_accum > 1 splits the batch into microbatches scanned serially —
    the standard memory/throughput trade (and a compute/comm overlap point:
    the per-microbatch psum pipeline overlaps with the next microbatch's
    backward under GSPMD)."""
    ac = ac or (lambda x, kind=None: x)
    if grad_accum is None:
        grad_accum = cfg.grad_accum

    def loss(params, batch):
        return MD.loss_fn(cfg, params, batch, ac)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            lv, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def micro(carry, mb):
                acc, _ = carry
                lv, g = jax.value_and_grad(loss)(params, mb)
                acc = jax.tree.map(lambda a, b: a + b / grad_accum, acc, g)
                return (acc, lv), None

            def split(x, key):
                ga = grad_accum
                bd = 1 if key == "positions" else 0   # positions: (3, B, S)
                nb = x.shape[bd] // ga
                if bd == 0:
                    return x.reshape((ga, nb) + x.shape[1:])
                return x.reshape(x.shape[:1] + (ga, nb)
                                 + x.shape[2:]).swapaxes(0, 1)

            mbatch = {k: split(v, k) for k, v in batch.items()}
            zeros = jax.tree.map(jnp.zeros_like, params)
            (grads, lv), _ = jax.lax.scan(micro, (zeros, jnp.asarray(0.0)),
                                          mbatch)
        if compress_fn is not None:
            grads = compress_fn(grads)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": lv, "grad_norm": gnorm}

    return step


def make_prefill_step(cfg: ModelConfig, ac: Callable = None):
    """Forward over the full prompt; returns last-position logits."""
    ac = ac or (lambda x, kind=None: x)

    def prefill(params, batch):
        x, _ = MD.forward(cfg, params, batch["tokens"],
                          batch.get("vision_embeds"), batch.get("positions"),
                          ac)
        lg = MD.logits_fn(cfg, params, x[:, -1:])
        return lg[:, 0]

    return prefill


def make_serve_step(cfg: ModelConfig, ac: Callable = None,
                    sample: str = "greedy"):
    """One decode iteration: logits -> next token -> updated cache."""
    ac = ac or (lambda x, kind=None: x)

    def serve(params, cache, tokens, position):
        lg, cache = MD.decode_step(cfg, params, cache, tokens, position, ac)
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return nxt, lg, cache

    return serve

"""GSPMD sharding rules for every parameter / activation / cache tensor.

Policy (megatron-style TP on 'model' + ZeRO-3/FSDP on the data axes for
large models):

    column-parallel weights (wq/wk/wv/w1/w3/in_proj/dt_proj, lm head) shard
    their *output* dim on 'model' and (if fsdp) their input dim on DP;
    row-parallel weights (wo/w2/out_proj) the transpose;
    MoE expert tensors shard the expert d_ff on 'model' (EP==TP axis);
    embeddings shard the vocab on 'model';
    optimizer state inherits the parameter specs (ZeRO falls out for free).

Every rule is divisibility-guarded: if a dim doesn't divide by the mesh axis
the entry degrades to None (replicated) — this is what lets one rule set
serve 10 architectures with head counts from 1 to 96.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.launch.mesh import data_axes
from repro.models.config import ModelConfig

# last-two-dims rule per leaf name: (in_rule, out_rule) where rule is
# 'tp' | 'dp' | None  (dp = FSDP axes, only applied when fsdp enabled)
_MATMUL_RULES = {
    "wq": ("dp", "tp"), "wk": ("dp", "tp"), "wv": ("dp", "tp"),
    "wo": ("tp", "dp"),
    "w1": ("dp", "tp"), "w3": ("dp", "tp"), "w2": ("tp", "dp"),
    "in_proj": ("dp", "tp"), "out_proj": ("tp", "dp"),
    "x_proj": ("tp", None), "dt_proj": (None, "tp"),
    "router": ("dp", None),
    "embed": ("tp", "dp"),       # (V, d): vocab on model
    "head": ("dp", "tp"),        # (d, V): vocab on model
}
_VECTOR_RULES = {
    "conv_w": (None, "tp"),      # (K, di)
    "conv_b": ("tp",),
    "dt_bias": ("tp",),
    "D": ("tp",),
    "A_log": ("tp", None),       # (di, N)
}


def _axis_ok(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    size = int(np.prod([mesh.shape[a] for a in (
        (axes,) if isinstance(axes, str) else axes)]))
    return dim % size == 0 and size > 1


def _resolve(rule, mesh: Mesh, dim: int, fsdp: bool):
    if rule == "tp":
        return "model" if _axis_ok(dim, mesh, "model") else None
    if rule == "dp":
        if not fsdp:
            return None
        dp = data_axes(mesh)
        return dp if _axis_ok(dim, mesh, dp) else None
    return None


def param_pspec(path, shape, mesh: Mesh, fsdp: bool) -> P:
    name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
    nd = len(shape)
    if name in _MATMUL_RULES and nd >= 2:
        rin, rout = _MATMUL_RULES[name]
        spec = [None] * nd
        spec[-2] = _resolve(rin, mesh, shape[-2], fsdp)
        spec[-1] = _resolve(rout, mesh, shape[-1], fsdp)
        return P(*spec)
    if name in _VECTOR_RULES:
        rules = _VECTOR_RULES[name]
        spec = [None] * nd
        for i, r in enumerate(rules):
            dim_idx = nd - len(rules) + i
            spec[dim_idx] = _resolve(r, mesh, shape[dim_idx], fsdp)
        return P(*spec)
    return P()   # norms, biases, scalars: replicated


def _all_axes(mesh: Mesh) -> tuple:
    return tuple(mesh.axis_names)


def param_shardings(cfg: ModelConfig, abstract_params, mesh: Mesh,
                    fsdp: Optional[bool] = None):
    if fsdp is None:
        fsdp = cfg.n_params() > 3e9 or cfg.pure_dp
    if cfg.pure_dp:
        # fold 'model' into data parallelism: params fully sharded (ZeRO-3)
        # over every mesh axis on their largest divisible dim, no TP.
        axes = _all_axes(mesh)

        def g(path, leaf):
            spec = [None] * len(leaf.shape)
            if fsdp:
                dims = sorted(range(len(leaf.shape)),
                              key=lambda i: -leaf.shape[i])
                for i in dims:
                    if _axis_ok(leaf.shape[i], mesh, axes):
                        spec[i] = axes
                        break
            return NamedSharding(mesh, P(*spec))

        return compat.tree_map_with_path(g, abstract_params)

    def f(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf.shape, mesh, fsdp))
    return compat.tree_map_with_path(f, abstract_params)


# ----------------------------- activations -------------------------------- #
def make_ac(mesh: Mesh, cfg: ModelConfig):
    """Activation-constraint callback threaded through the model: keeps the
    batch dim on DP and (for logits) the vocab dim on 'model'."""
    dp = _all_axes(mesh) if cfg.pure_dp else data_axes(mesh)

    def ac(x, kind="act"):
        if kind == "moe_gecd":
            # grouped dispatch buffer (G, E, C, d): groups follow the batch
            # onto DP; the expert FFN's d_ff stays sharded over 'model'.
            spec = [None] * x.ndim
            if _axis_ok(x.shape[0], mesh, dp):
                spec[0] = dp
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        if kind == "logits":
            spec = [None] * x.ndim
            if x.shape[0] % max(int(np.prod([mesh.shape[a] for a in dp])), 1) == 0:
                spec[0] = dp
            if not cfg.pure_dp and _axis_ok(x.shape[-1], mesh, "model"):
                spec[-1] = "model"
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
        spec = [None] * x.ndim
        if x.ndim >= 2 and x.shape[0] % max(
                int(np.prod([mesh.shape[a] for a in dp])), 1) == 0 and x.shape[0] > 1:
            spec[0] = dp
        if cfg.seq_shard and x.ndim == 3 and _axis_ok(x.shape[1], mesh, "model"):
            spec[1] = "model"     # sequence parallelism (hillclimb lever)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))

    return ac


# ----------------------------- batches / caches --------------------------- #
def batch_pspec(shape, mesh: Mesh, batch_dim: int = 0, dp=None) -> P:
    dp = dp or data_axes(mesh)
    spec = [None] * len(shape)
    if _axis_ok(shape[batch_dim], mesh, dp):
        spec[batch_dim] = dp
    return P(*spec)


def batch_shardings(batch_specs: dict, mesh: Mesh, batch_dims: dict = None,
                    pure_dp: bool = False):
    batch_dims = batch_dims or {}
    dp = _all_axes(mesh) if pure_dp else None
    out = {}
    for k, v in batch_specs.items():
        bd = batch_dims.get(k, 1 if k == "positions" else 0)
        if v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = NamedSharding(mesh, batch_pspec(v.shape, mesh, bd, dp))
    return out


def cache_pspec(name: str, shape, mesh: Mesh) -> P:
    """KV cache (L,B,S,KV,hd) / SSM caches (L,B,*,di,*)."""
    dp = data_axes(mesh)
    spec = [None] * len(shape)
    if name in ("k", "v"):
        if _axis_ok(shape[1], mesh, dp):
            spec[1] = dp
        elif _axis_ok(shape[2], mesh, dp):
            spec[2] = dp          # long-context batch=1: shard sequence on DP
        if _axis_ok(shape[3], mesh, "model"):
            spec[3] = "model"
        elif _axis_ok(shape[4], mesh, "model"):
            spec[4] = "model"
    elif name == "conv":
        if _axis_ok(shape[1], mesh, dp):
            spec[1] = dp
        if _axis_ok(shape[3], mesh, "model"):
            spec[3] = "model"
    elif name == "ssm":
        if _axis_ok(shape[1], mesh, dp):
            spec[1] = dp
        if _axis_ok(shape[2], mesh, "model"):
            spec[2] = "model"
    return P(*spec)


def cache_shardings(cache_specs: dict, mesh: Mesh):
    return {k: NamedSharding(mesh, cache_pspec(k, v.shape, mesh))
            for k, v in cache_specs.items()}

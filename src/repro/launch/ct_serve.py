"""Recon-as-a-service: geometry-bucketed dynamic batching for CT requests.

The LM side of the repo serves token streams with fixed decode slots
(:mod:`repro.launch.serve`); this module is the CT analogue.  A scanner farm
produces a stream of small reconstruction jobs, most sharing a handful of
protocol geometries.  The server

  * **buckets** incoming requests by ``(tier, solver, spec.bucket_key(),
    solver kwargs)`` — two requests may share a packed batch iff their
    :class:`~repro.core.spec.ProjectorSpec` hashes equal (same geometry
    content, kernels, mode, precision), so one compiled executable covers
    the whole batch;
  * **packs** same-bucket requests into one batched dispatch: the kernels
    fold ``batch x n_rows`` onto the 128-wide TPU lane axis, so e.g. 128
    single-row 2D recons fill the lanes of a single kernel launch;
  * serves **tiered latency classes** — ``interactive`` (single-shot
    FBP/FDK) is dispatched strictly before ``quality`` (iterative
    sirt / cgls / fista_tv);
  * guarantees a **warm request path**: :meth:`CTServer.warm` primes the op
    cache and the jitted per-(bucket, size-class) executors, and the
    autotuner's disk cache (``~/.cache/repro/tune.json``) is consulted
    before any sweep, so a primed server answers traffic with zero
    compilation and zero autotune sweeps (observable via
    ``repro.kernels.ops.cache_stats`` and ``repro.kernels.tune.sweep_count``);
  * **isolates failures** per request: a request that fails validation or
    crashes its executor is answered with ``ok=False`` and its error
    message — batch mates are re-run individually and still succeed.

    >>> srv = CTServer(max_batch=16)
    >>> srv.warm(spec, "fbp")
    >>> rid = srv.submit(ReconRequest(spec=spec, sino=y, solver="fbp"))
    >>> done = srv.drain()
    >>> done[rid].image
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.projector import Projector
from repro.core.spec import ProjectorSpec
from repro.recon import cgls, fista_tv, sirt
from repro.recon.fista_tv import power_iteration
from repro.recon.result import ReconResult

__all__ = ["ReconRequest", "ReconResponse", "CTServer", "TIERS",
           "TIER_SOLVERS", "solver_tier"]

# Latency classes, in strict dispatch-priority order.
TIERS = ("interactive", "quality")
TIER_SOLVERS = {
    "interactive": ("fbp",),                      # single-shot FBP / FDK
    "quality": ("sirt", "cgls", "fista_tv"),      # iterative
}
_SOLVERS = {"sirt": sirt, "cgls": cgls, "fista_tv": fista_tv}


def solver_tier(solver: str) -> str:
    for tier, names in TIER_SOLVERS.items():
        if solver in names:
            return tier
    raise ValueError(f"unknown solver {solver!r}; expected one of "
                     f"{sorted(n for v in TIER_SOLVERS.values() for n in v)}")


@dataclasses.dataclass
class ReconRequest:
    """One reconstruction job: a sinogram plus the spec describing its
    operator.  ``solver_kwargs`` must be JSON-canonicalizable scalars
    (``n_iters``, ``beta``, ...) — they are part of the bucket identity,
    since requests in one packed batch share a single compiled solver."""

    spec: ProjectorSpec
    sino: Any
    solver: str = "fbp"
    solver_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    rid: Optional[int] = None                     # assigned at submit()


@dataclasses.dataclass
class ReconResponse:
    rid: int
    ok: bool
    tier: str
    solver: str
    result: Optional[ReconResult] = None          # None iff not ok
    error: Optional[str] = None
    bucket: Optional[str] = None
    batch_size: int = 0                           # real requests in the pack
    latency_s: float = 0.0                        # submit -> answered

    @property
    def image(self):
        return None if self.result is None else self.result.image


def _size_class(n: int, max_batch: int) -> int:
    """Next power of two >= n, capped at max_batch — bounds the number of
    compiled executables per bucket to log2(max_batch)+1."""
    c = 1
    while c < n and c < max_batch:
        c *= 2
    return c


class CTServer:
    """Geometry-bucketed dynamic batcher over the projector stack.

    Synchronous by design (like :class:`repro.launch.serve.Server`): callers
    ``submit`` then ``drain``/``step``.  ``max_batch=1`` degenerates to a
    serial per-request loop — the baseline the throughput bench compares
    against.
    """

    def __init__(self, max_batch: int = 16):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = max_batch
        # bucket key -> FIFO of (request, submit time)
        self._queues: Dict[Tuple, List[Tuple[ReconRequest, float]]] = {}
        self._bucket_meta: Dict[Tuple, ReconRequest] = {}
        # (bucket key, size class) -> jitted executor
        self._executors: Dict[Tuple, Any] = {}
        self._responses: Dict[int, ReconResponse] = {}
        self._next_rid = 0
        #: one record per packed dispatch: {"bucket", "tier", "solver",
        #: "rids", "size_class", "wall_s"} — tests assert heterogeneous
        #: specs never appear in one record.
        self.dispatch_log: List[Dict[str, Any]] = []

    # -- admission ---------------------------------------------------------- #
    @staticmethod
    def bucket_key(req: ReconRequest) -> Tuple:
        tier = solver_tier(req.solver)
        kwargs = json.dumps(sorted(req.solver_kwargs.items()), default=float)
        return (tier, req.solver, req.spec.bucket_key(), kwargs)

    def submit(self, req: ReconRequest) -> int:
        """Admit one request.  Validation failures are answered immediately
        (``ok=False``) without ever reaching a batch."""
        rid = self._next_rid if req.rid is None else req.rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = dataclasses.replace(req, rid=rid)
        try:
            tier = solver_tier(req.solver)
            if not isinstance(req.spec, ProjectorSpec):
                raise TypeError(f"ReconRequest.spec must be a ProjectorSpec, "
                                f"got {type(req.spec).__name__}")
            expect = req.spec.geom.sino_shape
            if tuple(req.sino.shape) != tuple(expect):
                raise ValueError(f"sinogram shape {tuple(req.sino.shape)} "
                                 f"does not match spec's {tuple(expect)}")
            key = self.bucket_key(req)
        except Exception as e:                    # noqa: BLE001
            self._responses[rid] = ReconResponse(
                rid=rid, ok=False, tier="?", solver=req.solver,
                error=f"{type(e).__name__}: {e}")
            return rid
        self._queues.setdefault(key, []).append((req, time.perf_counter()))
        self._bucket_meta.setdefault(key, req)
        return rid

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # -- executors ---------------------------------------------------------- #
    def _solver_fn(self, req: ReconRequest):
        proj = Projector(req.spec)
        kwargs = dict(req.solver_kwargs)
        if req.solver == "fbp":
            def fn(y):
                img = proj.fbp(y, **kwargs)
                hist = jnp.zeros(y.shape[:-3] + (0,), img.dtype)
                return ReconResult(image=img, iterations=0,
                                   residual_history=hist)
            return fn
        if req.solver == "fista_tv" and "L" not in kwargs:
            # The Lipschitz constant is a property of the operator — compute
            # it once at executor-build time, not inside every traced call.
            kwargs["L"] = float(power_iteration(proj)) * 1.05
        solve = _SOLVERS[req.solver]
        return lambda y: solve(proj, y, **kwargs)

    def _executor(self, key: Tuple, size: int):
        ex = self._executors.get((key, size))
        if ex is None:
            ex = jax.jit(self._solver_fn(self._bucket_meta[key]))
            self._executors[(key, size)] = ex
        return ex

    def warm(self, spec: ProjectorSpec, solver: str = "fbp",
             solver_kwargs: Optional[Dict[str, Any]] = None,
             batch_sizes: Optional[Tuple[int, ...]] = None) -> None:
        """Prime every compiled artifact a bucket's traffic will touch:
        the op cache (kernel matched pairs), the tune registry (reads the
        persisted disk cache if present), and one jitted executor per batch
        size class.  After this, requests for the bucket run with zero
        compiles and zero autotune sweeps."""
        proto = ReconRequest(spec=spec, sino=jnp.zeros(spec.geom.sino_shape),
                             solver=solver,
                             solver_kwargs=dict(solver_kwargs or {}))
        key = self.bucket_key(proto)
        self._bucket_meta.setdefault(key, proto)
        if batch_sizes is None:
            sizes, c = [], 1
            while c <= self.max_batch:
                sizes.append(c)
                c *= 2
            batch_sizes = tuple(sizes)
        for n in batch_sizes:
            size = _size_class(n, self.max_batch)
            y = jnp.zeros((size,) + tuple(spec.geom.sino_shape))
            jax.block_until_ready(self._executor(key, size)(y).image)

    # -- dispatch ----------------------------------------------------------- #
    def _pick_bucket(self) -> Optional[Tuple]:
        """Strict tier priority; FIFO (oldest queued request) within a
        tier so no bucket starves another of the same class."""
        best, best_t = None, None
        for tier in TIERS:                        # priority order
            for key, q in self._queues.items():
                if key[0] != tier or not q:
                    continue
                if best_t is None or q[0][1] < best_t:
                    best, best_t = key, q[0][1]
            if best is not None:
                return best
        return None

    def step(self) -> bool:
        """Dispatch one packed batch (the oldest highest-tier bucket).
        Returns False when no work is queued."""
        key = self._pick_bucket()
        if key is None:
            return False
        q = self._queues[key]
        take, q[:] = q[:self.max_batch], q[self.max_batch:]
        reqs = [r for r, _ in take]
        t_sub = [t for _, t in take]
        tier, solver = key[0], key[1]
        n = len(reqs)
        size = _size_class(n, self.max_batch)
        # Pack on the host: an eager jnp.stack over N tiny device arrays is
        # an N-operand concat whose dispatch overhead (~0.7ms at N=16) would
        # eat the batching win; one numpy stack + a single transfer is flat.
        sinos = [np.asarray(r.sino) for r in reqs]
        batch = np.stack(sinos + [np.zeros_like(sinos[0])] * (size - n))
        t0 = time.perf_counter()
        try:
            out = self._executor(key, size)(batch)
            # Unpack on the host: per-index device gathers would each
            # compile a tiny executable and poke holes in the warm path.
            img = np.asarray(out.image)
            hist = np.asarray(out.residual_history)
            results: List[Optional[ReconResult]] = [
                ReconResult(image=img[i], iterations=out.iterations,
                            residual_history=hist[i])
                for i in range(n)]
            errors: List[Optional[str]] = [None] * n
        except Exception:                         # noqa: BLE001
            # Per-request isolation: re-run the batch members one by one so
            # a single poisoned request cannot take down its batch mates.
            results, errors = [], []
            for r in reqs:
                try:
                    out = self._executor(key, 1)(
                        jnp.asarray(r.sino)[None])
                    results.append(ReconResult(
                        image=np.asarray(out.image)[0],
                        iterations=out.iterations,
                        residual_history=np.asarray(out.residual_history)[0]))
                    errors.append(None)
                except Exception as e:            # noqa: BLE001
                    results.append(None)
                    errors.append(f"{type(e).__name__}: {e}")
        t1 = time.perf_counter()
        self.dispatch_log.append({
            "bucket": key[2], "tier": tier, "solver": solver,
            "rids": [r.rid for r in reqs], "size_class": size,
            "wall_s": t1 - t0})
        for r, ts, res, err in zip(reqs, t_sub, results, errors):
            self._responses[r.rid] = ReconResponse(
                rid=r.rid, ok=err is None, tier=tier, solver=solver,
                result=res, error=err, bucket=key[2], batch_size=n,
                latency_s=t1 - ts)
        return True

    def drain(self) -> Dict[int, ReconResponse]:
        """Run steps until every queued request is answered; returns all
        responses accumulated so far, keyed by rid."""
        while self.step():
            pass
        return dict(self._responses)

    def take_responses(self) -> Dict[int, ReconResponse]:
        out, self._responses = self._responses, {}
        return out

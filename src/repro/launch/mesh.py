"""Production mesh construction.

A *function*, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 placeholder devices via XLA_FLAGS).

Mesh layout (TPU v5e pods):
    single pod : (16, 16)      -> ('data', 'model')      = 256 chips
    multi pod  : (2, 16, 16)   -> ('pod', 'data', 'model') = 512 chips

'model' is the tensor/expert-parallel axis (fast ICI dimension); 'data' is
data/FSDP; 'pod' is pure-DP across the slower inter-pod links (gradient
all-reduce only, overlappable with the backward pass).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Default production mesh is (16,16) / (2,16,16).  ``shape`` overrides
    the logical split over the same chips (perf experiments, e.g. (64,4)
    for sub-3B models where TP=16 is collective-bound — see §Perf)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model")[-len(shape):]
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Tiny mesh over whatever devices exist (tests / CPU smoke training)."""
    n = jax.device_count()
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh ('pod' folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


def tp_size(mesh) -> int:
    return int(mesh.shape.get("model", 1))

"""Builds the EXPERIMENTS.md roofline tables from the dry-run JSON records +
the analytic model (see analysis.py for why both are needed).

    PYTHONPATH=src python -m repro.launch.report --dryrun results/dryrun
"""
from __future__ import annotations

import argparse
import json
import os

from repro import configs
from repro.launch import analysis, roofline
from repro.launch.shapes import SHAPES


def _fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def cell_row(arch: str, shape_name: str, rec: dict, tp=16, dp=16):
    # dp folds the pod axis in: multi-pod (2,16,16) -> dp=32, tp=16
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    a = analysis.analytic_cell(cfg, shape, tp=tp, dp=dp)
    n = rec.get("n_devices", 256)
    t = roofline.terms(a["flops"], a["hbm_bytes"], a["collective_bytes"], n)
    mf = roofline.model_flops(cfg, shape, a["kind"])
    t["useful_fraction"] = mf / max(a["flops"], 1.0)
    t["mfu"] = mf / (n * roofline.PEAK_FLOPS * max(t["step_s"], 1e-12))
    # validation: reconstruct what cost_analysis should see (loops counted once)
    meas = (rec.get("cost", {}).get("flops") or 0.0) * n
    pred = analysis.hlo_counted_flops(cfg, shape)
    t["hlo_validation"] = meas / pred if pred else float("nan")
    t["analytic"] = a
    t["hlo_measured_flops"] = meas
    # measured collectives with the layer-loop multiplier heuristic:
    coll = rec.get("collectives", {})
    per_comp = coll.get("per_computation_bytes", {})
    hlo_coll = 0.0
    for comp, b in per_comp.items():
        mult = cfg.n_layers if ("region" in comp or "while" in comp
                                or "body" in comp) else 1
        hlo_coll += b * mult
    t["hlo_collective_bytes"] = hlo_coll * n
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/roofline.md")
    args = ap.parse_args()

    rows = []
    for arch in configs.ARCHS:
        for shape_name in SHAPES:
            fn = os.path.join(args.dryrun,
                              f"{arch}-{shape_name}-{args.mesh}.json")
            if not os.path.exists(fn):
                continue
            rec = json.load(open(fn))
            if rec["status"] == "skipped":
                rows.append((arch, shape_name, None, rec["reason"]))
                continue
            if rec["status"] != "ok":
                rows.append((arch, shape_name, None, "ERROR"))
                continue
            t = cell_row(arch, shape_name, rec,
                         dp=(32 if args.mesh == "multi" else 16))
            t["temp_gib"] = (rec["memory"]["temp_bytes"] or 0) / 2 ** 30
            t["compile_s"] = rec.get("compile_s")
            rows.append((arch, shape_name, t, None))

    lines = ["| arch | shape | compute | memory | collective | bound | "
             "roofline-frac | MODEL/HLO | MFU@roof | temp/dev | HLOval |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for arch, shape, t, note in rows:
        if t is None:
            lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | — "
                         f"| — | {note[:60]} |")
            continue
        lines.append(
            f"| {arch} | {shape} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"{t['bound']} | {t['roofline_fraction']:.2f} | "
            f"{t['useful_fraction']:.2f} | {t['mfu']:.2f} | "
            f"{t['temp_gib']:.1f}GiB | {t['hlo_validation']:.2f} |")
    out = "\n".join(lines)
    with open(args.out, "w") as f:
        f.write(out + "\n")
    print(out)


if __name__ == "__main__":
    main()

"""Mixture-of-Experts layer (grok-1 / olmoe style: softmax router, top-k).

Two implementations, selectable per config (hillclimb lever, see
EXPERIMENTS.md §Perf):

* ``dense``  — every expert runs on every token, combined with the (sparse)
  gate weights.  Simple, deterministic, load-balance-free; wastes
  n_experts/top_k x FLOPs.  This is the paper-agnostic baseline.
* ``ragged`` — tokens are sorted by expert assignment and processed with
  ``jax.lax.ragged_dot`` (grouped matmul); FLOPs are proportional to the
  *active* parameter count.

Expert weights are sharded over the 'model' mesh axis (expert-parallel =
tensor-parallel axis); the router is replicated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def moe_param_shapes(cfg: ModelConfig):
    d = cfg.d_model
    m = cfg.moe
    ff = m.expert_d_ff
    shapes = {"router": (d, m.n_experts),
              "w1": (m.n_experts, d, ff), "w2": (m.n_experts, ff, d)}
    if cfg.mlp == "swiglu":
        shapes["w3"] = (m.n_experts, d, ff)
    return shapes


def _expert_ffn(params, x, kind):
    """x: (E, T, d) — per-expert batch."""
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("etd,edf->etf", x, params["w1"]))
        h = h * jnp.einsum("etd,edf->etf", x, params["w3"])
    elif kind == "sq_relu":
        h = jnp.square(jax.nn.relu(jnp.einsum("etd,edf->etf", x, params["w1"])))
    else:
        h = jax.nn.gelu(jnp.einsum("etd,edf->etf", x, params["w1"]))
    return jnp.einsum("etf,efd->etd", h, params["w2"])


def _router(params, x, cfg: ModelConfig):
    """x: (T, d) -> gates (T, k), experts (T, k), probs (T, E)."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, cfg.moe.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, experts, probs


def moe_dense(params, x, cfg: ModelConfig):
    """x: (B, S, d).  All-experts path."""
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    gates, experts, probs = _router(params, xt, cfg)
    E = cfg.moe.n_experts
    xe = jnp.broadcast_to(xt[None], (E, B * S, d))
    ye = _expert_ffn(params, xe, cfg.mlp)                 # (E, T, d)
    # combine: one-hot over the small E axis only (T x k x E)
    onehot = jax.nn.one_hot(experts, E, dtype=x.dtype)    # (T, k, E)
    comb = jnp.einsum("tke,tk->te", onehot, gates.astype(x.dtype))
    y = jnp.einsum("etd,te->td", ye, comb)
    return y.reshape(B, S, d), _aux_loss(probs, experts, E)


def moe_ragged(params, x, cfg: ModelConfig):
    """Sorted/grouped-matmul path: FLOPs ~ active params only."""
    B, S, d = x.shape
    k = cfg.moe.top_k
    E = cfg.moe.n_experts
    T = B * S
    xt = x.reshape(T, d)
    gates, experts, probs = _router(params, xt, cfg)
    flat_e = experts.reshape(T * k)                        # expert id per slot
    flat_g = gates.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e)
    xs = xt[flat_t[order]]                                 # (T*k, d) sorted
    group_sizes = jnp.bincount(flat_e, length=E)
    h = jax.lax.ragged_dot(xs, params["w1"], group_sizes)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(h) * jax.lax.ragged_dot(xs, params["w3"], group_sizes)
    elif cfg.mlp == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    ys = jax.lax.ragged_dot(h, params["w2"], group_sizes)  # (T*k, d)
    # un-sort and combine
    y = jnp.zeros((T, d), x.dtype)
    y = y.at[flat_t[order]].add(ys * flat_g[order][:, None].astype(x.dtype))
    return y.reshape(B, S, d), _aux_loss(probs, experts, E)


def _aux_loss(probs, experts, E):
    """Load-balancing auxiliary loss (Switch-style)."""
    onehot = jax.nn.one_hot(experts[..., 0], E, dtype=jnp.float32)
    frac_tokens = onehot.mean(axis=0)
    frac_probs = probs.mean(axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


def moe_gather(params, x, cfg: ModelConfig, ac=None):
    """Grouped capacity-based gather dispatch (GShard-style, GSPMD-friendly).

    Each *group* (= one sequence; groups shard over the data axes exactly
    like the batch) dispatches its tokens to a per-group per-expert capacity
    buffer (G, E, C, d); the expert FFN einsum then does ~k/E of the
    dense-MoE FLOPs while the expert d_ff dim stays sharded over 'model'
    (so any expert count works, incl. grok's E=8 on a 16-way axis).
    Overflowing tokens are dropped (standard capacity semantics); the aux
    loss keeps the router balanced so drops are rare.

    Iteration history (EXPERIMENTS.md §Perf):
      v1 sorted ragged_dot   — REFUTED: defeats GSPMD (6.7x flops).
      v2 global (E, C, d)    — flops /2.4 but dispatch resharding exploded
                               (gather crossed the data->model shard
                               boundary: +100GB/dev collectives).
      v3 grouped (this)      — dispatch is group-local; groups never leave
                               their data shard."""
    B, S, d = x.shape
    m = cfg.moe
    k, E = m.top_k, m.n_experts
    cf = 1.25
    C = max(4, int(round((k * S / E) * cf)))
    gates, experts, probs = _router(params, x.reshape(B * S, d), cfg)
    experts = experts.reshape(B, S, k)
    gates = gates.reshape(B, S, k).astype(x.dtype)

    flat_e = experts.reshape(B, S * k)
    flat_t = jnp.broadcast_to(jnp.repeat(jnp.arange(S), k)[None], (B, S * k))
    order = jnp.argsort(flat_e, axis=1)                    # per-group sort
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(E)))(sorted_e)
    rank = jnp.arange(S * k)[None] - jnp.take_along_axis(
        starts, sorted_e, axis=1)
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)     # E*C = drop bin
    src_tok = jnp.take_along_axis(flat_t, order, axis=1)   # (B, S*k)
    gathered = jnp.take_along_axis(
        x, src_tok[:, :, None], axis=1)                    # (B, S*k, d)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, gathered)
    gecd = buf[:, :-1].reshape(B, E, C, d)
    if ac is not None:
        gecd = ac(gecd, "moe_gecd")
    ye = _expert_ffn_grouped(params, gecd, cfg.mlp)        # (B, E, C, d)
    if ac is not None:
        ye = ac(ye, "moe_gecd")
    out = jnp.concatenate([ye.reshape(B, E * C, d),
                           jnp.zeros((B, 1, d), x.dtype)], axis=1)
    contrib = jax.vmap(lambda o, s: o[s])(out, jnp.where(keep, slot, E * C))
    sorted_g = jnp.take_along_axis(gates.reshape(B, S * k), order, axis=1)
    contrib = contrib * sorted_g[:, :, None]
    y = jnp.zeros((B, S, d), x.dtype)
    y = jax.vmap(lambda yy, t, c: yy.at[t].add(c))(y, src_tok, contrib)
    return y, _aux_loss(probs, experts.reshape(B * S, k), E)


def _expert_ffn_grouped(params, gecd, kind):
    """gecd: (G, E, C, d) -> (G, E, C, d); expert d_ff sharded over 'model'."""
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", gecd, params["w1"]))
        h = h * jnp.einsum("gecd,edf->gecf", gecd, params["w3"])
    elif kind == "sq_relu":
        h = jnp.square(jax.nn.relu(
            jnp.einsum("gecd,edf->gecf", gecd, params["w1"])))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", gecd, params["w1"]))
    return jnp.einsum("gecf,efd->gecd", h, params["w2"])


def moe_apply(params, x, cfg: ModelConfig, ac=None):
    if cfg.moe.impl == "ragged":
        return moe_ragged(params, x, cfg)
    if cfg.moe.impl == "gather":
        return moe_gather(params, x, cfg, ac)
    return moe_dense(params, x, cfg)

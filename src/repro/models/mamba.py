"""Mamba-1 selective-state-space block (falcon-mamba / hymba SSM heads).

Training path: chunked selective scan — within a chunk the recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ,   y_t = C_t . h_t + D x_t

is evaluated with ``jax.lax.associative_scan`` (log-depth, TPU-friendly) and
chunks are threaded serially with ``lax.scan``, keeping the materialized
state tensor at (B, chunk, d_inner, N) instead of (B, S, d_inner, N) — the
memory shape that makes 500k-token contexts feasible.

Decode path: O(1) per token (the whole point of SSMs for long context).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def ssm_param_shapes(cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm.d_state
    R = cfg.ssm.resolved_dt_rank(d)
    K = cfg.ssm.d_conv
    return {"in_proj": (d, 2 * di), "conv_w": (K, di), "conv_b": (di,),
            "x_proj": (di, R + 2 * N), "dt_proj": (R, di), "dt_bias": (di,),
            "A_log": (di, N), "D": (di,), "out_proj": (di, d)}


def _ssm_core(params, xc, dt, Bs, Cs, h0, cfg: ModelConfig):
    """One chunk of the selective scan.
    xc (B,C,di), dt (B,C,di), Bs/Cs (B,C,N), h0 (B,di,N)."""
    A = -jnp.exp(params["A_log"].astype(jnp.float32))       # (di, N)
    Abar = jnp.exp(dt[..., None] * A)                        # (B,C,di,N)
    Bx = (dt * xc)[..., None] * Bs[:, :, None, :]            # (B,C,di,N)

    def combine(p, q):
        a1, b1 = p
        a2, b2 = q
        return a2 * a1, b2 + a2 * b1

    Acum, Hcum = jax.lax.associative_scan(combine, (Abar, Bx), axis=1)
    h = Hcum + Acum * h0[:, None]                            # (B,C,di,N)
    y = jnp.einsum("bcdn,bcn->bcd", h, Cs)
    y = y + params["D"].astype(jnp.float32) * xc
    return y, h[:, -1]


def _dt_B_C(params, x, cfg: ModelConfig):
    """x: (B,*,di) -> dt (B,*,di) f32, Bs/Cs (B,*,N) f32."""
    N = cfg.ssm.d_state
    R = cfg.ssm.resolved_dt_rank(cfg.d_model)
    proj = x @ params["x_proj"]                              # (B,*,R+2N)
    dt_r, Bs, Cs = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(dt_r @ params["dt_proj"]
                         + params["dt_bias"]).astype(jnp.float32)
    return dt, Bs.astype(jnp.float32), Cs.astype(jnp.float32)


def mamba_train(params, x, cfg: ModelConfig, chunk: int = 512):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di = cfg.d_inner
    K = cfg.ssm.d_conv
    xz = x @ params["in_proj"]                               # (B,S,2di)
    xs, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv along S
    xpad = jnp.pad(xs, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S] * params["conv_w"][i] for i in range(K))
    xc = jax.nn.silu(xc + params["conv_b"])
    dt, Bs, Cs = _dt_B_C(params, xc, cfg)
    xcf = xc.astype(jnp.float32)

    C = min(chunk, S)
    nc = S // C
    if S % C:
        raise ValueError(f"sequence length {S} is not divisible by the ssm "
                         f"chunk size {C}; pad the sequence or pass a chunk "
                         f"that divides it")
    resh = lambda a: a.reshape(B, nc, C, *a.shape[2:]).swapaxes(0, 1)
    xs_c, dt_c, B_c, C_c = map(resh, (xcf, dt, Bs, Cs))

    def step(h, inp):
        xi, di_, bi, ci = inp
        y, h = _ssm_core(params, xi, di_, bi, ci, h, cfg)
        return h, y

    h0 = jnp.zeros((B, di, cfg.ssm.d_state), jnp.float32)
    _, ys = jax.lax.scan(step, h0, (xs_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(B, S, di)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ params["out_proj"]


def mamba_decode(params, x, cfg: ModelConfig, conv_state, ssm_state):
    """One-token decode.  x: (B, 1, d); conv_state (B, K-1, di);
    ssm_state (B, di, N).  Returns (y (B,1,d), conv_state, ssm_state)."""
    xz = x @ params["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)                        # (B,1,di)
    hist = jnp.concatenate([conv_state, xs], axis=1)         # (B,K,di)
    xc = jnp.einsum("bkd,kd->bd", hist, params["conv_w"])[:, None]
    xc = jax.nn.silu(xc + params["conv_b"])                  # (B,1,di)
    dt, Bs, Cs = _dt_B_C(params, xc, cfg)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    Abar = jnp.exp(dt[..., None] * A)[:, 0]                  # (B,di,N)
    Bx = ((dt * xc.astype(jnp.float32))[..., None]
          * Bs[:, :, None, :])[:, 0]                         # (B,di,N)
    ssm_state = Abar * ssm_state + Bx
    y = jnp.einsum("bdn,bn->bd", ssm_state, Cs[:, 0])
    y = y + params["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ params["out_proj"], hist[:, 1:], ssm_state


def init_ssm_params(key, cfg: ModelConfig, dtype):
    shapes = ssm_param_shapes(cfg)
    keys = jax.random.split(key, len(shapes))
    p = {}
    for (name, shp), k in zip(sorted(shapes.items()), keys):
        if name == "A_log":
            # S4D-real init: A = -(1..N)
            a = jnp.broadcast_to(jnp.arange(1, shp[1] + 1, dtype=jnp.float32),
                                 shp)
            p[name] = jnp.log(a)
        elif name == "D":
            p[name] = jnp.ones(shp, dtype)
        elif name == "dt_bias":
            # inverse-softplus of dt in [1e-3, 1e-1]
            dt = jnp.exp(jax.random.uniform(k, shp) *
                         (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
            p[name] = jnp.log(jnp.expm1(dt)).astype(dtype)
        elif name.endswith("_b") or name == "conv_b":
            p[name] = jnp.zeros(shp, dtype)
        else:
            fan_in = shp[0] if len(shp) > 1 else shp[0]
            p[name] = (jax.random.normal(k, shp, dtype)
                       * (1.0 / math.sqrt(fan_in)))
    return p

"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention (full,
sliding-window, chunked-online-softmax for long sequences, and single-step
decode against a KV cache), and the MLP variants used by the assigned archs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rms_norm(x, scale, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------- #
# Rotary embeddings
# --------------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                       # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...],
                theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE.  positions3: (3, ..., S) — temporal/h/w ids.
    ``sections`` split the half-dim; each section rotates with its own ids."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    # section id per frequency
    sec = []
    for i, s in enumerate(sections):
        sec += [i] * s
    sec = jnp.asarray(sec)                                 # (hd/2,)
    pos = jnp.take(positions3, sec, axis=0)                # (hd/2, ..., S)
    pos = jnp.moveaxis(pos, 0, -1)                         # (..., S, hd/2)
    ang = pos.astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------------- #
def _qkv(params, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    k = (x @ params["wk"]).reshape(B, S, KV, hd)
    v = (x @ params["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cfg.rope == "standard":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: ModelConfig):
    """q: (B,S,H,hd) k,v: (B,T,KV,hd); mask (S,T) bool (True=keep)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    q = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, H, hd)


def _causal_mask(S: int, T: int, window: Optional[int], is_global=None,
                 offset: int = 0):
    """(S, T) keep-mask.  ``is_global`` may be a *traced* per-layer bool
    (hybrid stacks inside lax.scan): global layers ignore the window."""
    qp = jnp.arange(S)[:, None] + offset
    kp = jnp.arange(T)[None, :]
    m = kp <= qp
    if window is not None:
        inw = kp > qp - window
        if is_global is None:
            m = m & inw
        else:
            m = m & (inw | is_global)
    return m


def attention_train(params, x, cfg: ModelConfig, positions,
                    window: Optional[int] = None, is_global=None,
                    chunk_q: int = 1024, chunk_kv: int = 1024):
    """Full-sequence causal attention.  Uses a chunked online-softmax path
    when S is large (memory O(S * chunk) instead of O(S^2))."""
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, x, cfg, positions)
    if S <= 2048:
        out = _sdpa(q, k, v, _causal_mask(S, S, window, is_global), cfg)
    else:
        out = _flash_attention(q, k, v, window, is_global, chunk_q, chunk_kv)
    out = out.reshape(B, S, H * hd)
    return out @ params["wo"]


def _flash_attention(q, k, v, window, is_global, cq: int, ck: int):
    """Chunked online-softmax attention (pure-jnp 'flash').  Off-diagonal
    fully-masked blocks are still computed (XLA cannot skip them); the Pallas
    flash kernel in kernels/flash.py removes that waste on TPU."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    cq = min(cq, S)
    ck = min(ck, S)
    nq, nk = S // cq, S // ck
    qc = q.reshape(B, nq, cq, KV, G, hd)
    kc = k.reshape(B, nk, ck, KV, hd)
    vc = v.reshape(B, nk, ck, KV, hd)
    scale = 1.0 / math.sqrt(hd)

    def q_block(qi, qb):               # qb: (B, cq, KV, G, hd)
        def kv_step(carry, ki):
            m, l, acc = carry
            kb = kc[:, ki]             # (B, ck, KV, hd)
            vb = vc[:, ki]
            s = jnp.einsum("bskgh,btkh->bkgst", qb, kb).astype(jnp.float32)
            s = s * scale
            qp = qi * cq + jnp.arange(cq)[:, None]
            kp = ki * ck + jnp.arange(ck)[None, :]
            keep = kp <= qp
            if window is not None:
                inw = kp > qp - window
                keep = keep & (inw if is_global is None else (inw | is_global))
            s = jnp.where(keep[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgst,btkh->bkgsh", p.astype(qb.dtype), vb).astype(jnp.float32)
            return (m_new, l_new, acc_new), 0

        m0 = jnp.full((B, KV, G, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out                     # (B, KV, G, cq, hd)

    outs = jax.lax.map(lambda i: q_block(i, qc[:, i]), jnp.arange(nq))
    # outs: (nq, B, KV, G, cq, hd) -> (B, S, H, hd)
    outs = jnp.moveaxis(outs, 0, 3)    # (B, KV, G, nq, cq, hd)
    B_, KV_, G_, nq_, cq_, hd_ = outs.shape
    outs = outs.reshape(B, KV_, G_, S, hd_)
    outs = jnp.moveaxis(outs, 3, 1)    # (B, S, KV, G, hd)
    return outs.reshape(B, S, KV_ * G_, hd_).astype(q.dtype)


def attention_decode(params, x, cfg: ModelConfig, cache_k, cache_v,
                     position, window: Optional[int] = None, is_global=None):
    """One-token decode.  cache_k/v: (B, S_max, KV, hd); position: (B,)
    per-sequence write index (continuous batching: every slot may be at a
    different depth).  Returns (out (B,1,d), new_k, new_v)."""
    B = x.shape[0]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    position = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    pos = position[:, None]                                     # (B, 1)
    if cfg.rope == "mrope":
        # decode: all three M-RoPE sections advance with the token index
        pos = jnp.broadcast_to(pos[None], (3, B, 1))
    q, k, v = _qkv(params, x, cfg, pos)
    S_max = cache_k.shape[1]
    ring = window is not None and S_max == window and is_global is None
    slot = jnp.mod(position, window) if ring else position      # (B,)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, slot].set(k[:, 0])
    cache_v = cache_v.at[bidx, slot].set(v[:, 0])
    kp = jnp.arange(S_max)[None, :]                             # (1, S)
    if ring:
        valid = kp < jnp.minimum(position + 1, window)[:, None]
    else:
        valid = kp <= position[:, None]
        if window is not None:
            inw = kp > (position[:, None] - window)
            valid = valid & (inw if is_global is None else (inw | is_global))
    q = q.reshape(B, 1, KV, H // KV, hd)
    s = jnp.einsum("bskgh,btkh->bkgst", q, cache_k).astype(jnp.float32)
    s = s / math.sqrt(hd)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, cache_v).reshape(B, 1, H * hd)
    return out @ params["wo"], cache_k, cache_v


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #
def mlp_apply(params, x, kind: str):
    if kind == "swiglu":
        return (jax.nn.silu(x @ params["w1"]) * (x @ params["w3"])) @ params["w2"]
    if kind == "sq_relu":
        return jnp.square(jax.nn.relu(x @ params["w1"])) @ params["w2"]
    if kind == "gelu":
        return jax.nn.gelu(x @ params["w1"]) @ params["w2"]
    raise ValueError(kind)


# --------------------------------------------------------------------------- #
# Parameter init helpers (used by model.init)
# --------------------------------------------------------------------------- #
def attn_param_shapes(cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    shapes = {"wq": (d, H * hd), "wk": (d, KV * hd), "wv": (d, KV * hd),
              "wo": (H * hd, d)}
    if cfg.qk_norm:
        shapes["q_norm"] = (hd,)
        shapes["k_norm"] = (hd,)
    return shapes


def mlp_param_shapes(cfg: ModelConfig):
    d, ff = cfg.d_model, cfg.d_ff
    if cfg.mlp == "swiglu":
        return {"w1": (d, ff), "w3": (d, ff), "w2": (ff, d)}
    return {"w1": (d, ff), "w2": (ff, d)}

"""Unified decoder LM covering every assigned architecture family.

One parameter tree, one ``loss`` (training) and one ``decode_step`` (serving)
entry point; the per-layer block is selected by ``cfg.family``:

    dense / vlm / audio : [attn] + [mlp]
    moe                 : [attn] + [moe]
    ssm                 : [mamba]
    hybrid (hymba)      : [attn || mamba  (parallel, mean-fused)] + [mlp]

Layers are stacked (leading L axis) and executed with ``jax.lax.scan`` so the
HLO stays one-layer-sized (compile time and remat both depend on this).
Heterogeneous per-layer attention windows (hymba: every k-th layer global,
rest sliding-window) are handled by running both masks' *metadata* through
the scan as a per-layer boolean.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.config import ModelConfig

_Id = lambda x, kind=None: x


# --------------------------------------------------------------------------- #
# Parameter shapes / init
# --------------------------------------------------------------------------- #
def layer_param_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    shapes = {}
    if cfg.uses_attention:
        shapes["attn"] = dict(L.attn_param_shapes(cfg), ln=(d,))
    if cfg.uses_ssm:
        shapes["ssm"] = dict(M.ssm_param_shapes(cfg),
                             **({} if cfg.family == "hybrid" else {}),
                             ln=(d,))
    if cfg.family == "moe":
        shapes["moe"] = dict(MOE.moe_param_shapes(cfg), ln=(d,))
    elif cfg.mlp != "none" and cfg.d_ff > 0:
        shapes["mlp"] = dict(L.mlp_param_shapes(cfg), ln=(d,))
    return shapes


def param_shapes(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    lsh = jax.tree.map(lambda s: (cfg.n_layers,) + s, layer_param_shapes(cfg),
                       is_leaf=lambda s: isinstance(s, tuple))
    out = {"embed": (cfg.n_codebooks, V, d), "final_norm": (d,),
           "layers": lsh}
    if not cfg.tie_embeddings:
        out["head"] = (cfg.n_codebooks, d, V)
    return out


def abstract_params(cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s, dt),
                        param_shapes(cfg),
                        is_leaf=lambda s: isinstance(s, tuple))


def init_params(cfg: ModelConfig, key):
    """Real (smoke-test-scale) initialization."""
    dt = jnp.dtype(cfg.param_dtype)
    shapes = param_shapes(cfg)
    flat, treedef = compat.tree_flatten_with_path(
        shapes, is_leaf=lambda s: isinstance(s, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for (path, shp), k in zip(flat, keys):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if "ln" in name or "norm" in name or name in ("D",):
            leaves.append(jnp.ones(shp, dt))
        elif name == "A_log":
            a = jnp.broadcast_to(
                jnp.arange(1, shp[-1] + 1, dtype=jnp.float32), shp)
            leaves.append(jnp.log(a).astype(jnp.float32))
        elif name == "dt_bias":
            dtv = jnp.exp(jax.random.uniform(k, shp)
                          * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
            leaves.append(jnp.log(jnp.expm1(dtv)).astype(dt))
        elif name.endswith("_b") or name == "bias":
            leaves.append(jnp.zeros(shp, dt))
        else:
            fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
            leaves.append(jax.random.normal(k, shp, dt)
                          / np.sqrt(max(fan_in, 1)))
    return jax.tree.unflatten(treedef, leaves)


# --------------------------------------------------------------------------- #
# Blocks
# --------------------------------------------------------------------------- #
def _layer_windows(cfg: ModelConfig) -> np.ndarray:
    """Per-layer: True = global attention, False = sliding window."""
    if cfg.sliding_window is None:
        return np.ones((cfg.n_layers,), bool)
    if cfg.global_attn_every <= 0:
        return np.zeros((cfg.n_layers,), bool)
    g = np.zeros((cfg.n_layers,), bool)
    g[::cfg.global_attn_every] = True
    g[-1] = True
    return g


_F32_LEAVES = {"A_log", "dt_bias", "D"}   # SSM dynamics stay fp32


def _cast_layer(lp, dtype):
    def f(path, a):
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if name in _F32_LEAVES or not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.astype(dtype)
    return compat.tree_map_with_path(f, lp)


def _block_train(cfg: ModelConfig, params, x, positions, is_global, ac):
    params = _cast_layer(params, jnp.dtype(cfg.compute_dtype))
    if cfg.family == "ssm":
        h = L.rms_norm(x, params["ssm"]["ln"], cfg.norm_eps)
        x = x + ac(M.mamba_train(params["ssm"], h, cfg))
        return x
    window = cfg.sliding_window
    if cfg.family == "hybrid":
        h = L.rms_norm(x, params["attn"]["ln"], cfg.norm_eps)
        a = L.attention_train(params["attn"], h, cfg, positions,
                              window=window, is_global=is_global)
        s = M.mamba_train(params["ssm"],
                          L.rms_norm(x, params["ssm"]["ln"], cfg.norm_eps),
                          cfg)
        x = x + ac(0.5 * (a + s))
    else:
        h = L.rms_norm(x, params["attn"]["ln"], cfg.norm_eps)
        x = x + ac(L.attention_train(params["attn"], h, cfg, positions,
                                     window=window, is_global=is_global))
    if "moe" in params:
        h = L.rms_norm(x, params["moe"]["ln"], cfg.norm_eps)
        y, aux = MOE.moe_apply(params["moe"], h, cfg, ac)
        x = x + ac(y)
    elif "mlp" in params:
        h = L.rms_norm(x, params["mlp"]["ln"], cfg.norm_eps)
        x = x + ac(L.mlp_apply(params["mlp"], h, cfg.mlp))
    return x


# --------------------------------------------------------------------------- #
# Forward (training)
# --------------------------------------------------------------------------- #
def _embed(cfg: ModelConfig, params, tokens, vision_embeds=None):
    """tokens: (B,S) or (B,nq,S) for multi-codebook."""
    emb = params["embed"]
    if cfg.n_codebooks > 1:
        x = sum(jnp.take(emb[q], tokens[:, q], axis=0)
                for q in range(cfg.n_codebooks))
    else:
        x = jnp.take(emb[0], tokens, axis=0)
    if vision_embeds is not None:
        x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
    return x.astype(jnp.dtype(cfg.compute_dtype))


def _positions(cfg: ModelConfig, B, S):
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if cfg.rope == "mrope":
        # text-only stub: all three sections share the temporal index
        return jnp.broadcast_to(pos[None], (3, B, S))
    return pos


def forward(cfg: ModelConfig, params, tokens, vision_embeds=None,
            positions=None, ac: Callable = _Id):
    x = _embed(cfg, params, tokens, vision_embeds)
    B, S, d = x.shape
    if positions is None:
        positions = _positions(cfg, B, S)
    x = ac(x, "act")
    windows = _layer_windows(cfg)

    def block(x, inp):
        lp, is_global = inp
        return _block_train(cfg, lp, x, positions, is_global, ac), None

    if cfg.remat_policy != "none":
        policy = (jax.checkpoint_policies.nothing_saveable
                  if cfg.remat_policy == "full"
                  else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        block = jax.checkpoint(block, policy=policy, prevent_cse=False)

    if cfg.scan_layers:
        x, _ = jax.lax.scan(block, x, (params["layers"], jnp.asarray(windows)))
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x, _ = block(x, (lp, jnp.asarray(windows[i])))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, ac


def logits_fn(cfg: ModelConfig, params, x, codebook: int = 0):
    head = (params["embed"].transpose(0, 2, 1) if cfg.tie_embeddings
            else params["head"])
    return x @ head[codebook].astype(x.dtype)


def loss_fn(cfg: ModelConfig, params, batch, ac: Callable = _Id):
    """batch: {'tokens': (B,S) or (B,nq,S), ['vision_embeds'], ['positions']}.
    Next-token cross entropy (text positions only for VLM)."""
    tokens = batch["tokens"]
    ve = batch.get("vision_embeds")
    x, _ = forward(cfg, params, tokens, ve, batch.get("positions"), ac)
    n_vis = 0 if ve is None else ve.shape[1]
    x = x[:, n_vis:]

    def ce(logits, labels):
        logits = ac(logits.astype(jnp.float32), "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        return (lse - gold).mean()

    total = 0.0
    if cfg.n_codebooks > 1:
        for q in range(cfg.n_codebooks):
            lg = logits_fn(cfg, params, x[:, :-1], q)
            total += ce(lg, tokens[:, q, 1:])
        total /= cfg.n_codebooks
    else:
        lg = logits_fn(cfg, params, x[:, :-1])
        total = ce(lg, tokens[:, 1:])
    return total


# --------------------------------------------------------------------------- #
# Decode (serving)
# --------------------------------------------------------------------------- #
def cache_shapes(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """Abstract KV/SSM cache spec.  Sliding-window layers use a ring buffer
    of the window size (sub-quadratic memory for 500k contexts)."""
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.compute_dtype)
    Lc = cfg.n_layers
    out = {}
    if cfg.uses_attention:
        s = seq_len
        if cfg.sliding_window is not None and cfg.global_attn_every <= 0:
            s = min(seq_len, cfg.sliding_window)
        elif cfg.sliding_window is not None:
            # hybrid stacks: scan needs homogeneous shapes; global layers
            # dominate, so allocate full length for all attention layers
            # unless every layer is windowed.
            s = seq_len
        out["k"] = jax.ShapeDtypeStruct((Lc, batch, s, cfg.n_kv_heads, hd), dt)
        out["v"] = jax.ShapeDtypeStruct((Lc, batch, s, cfg.n_kv_heads, hd), dt)
    if cfg.uses_ssm:
        out["conv"] = jax.ShapeDtypeStruct(
            (Lc, batch, cfg.ssm.d_conv - 1, cfg.d_inner), dt)
        out["ssm"] = jax.ShapeDtypeStruct(
            (Lc, batch, cfg.d_inner, cfg.ssm.d_state), jnp.float32)
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_shapes(cfg, batch, seq_len))


def decode_step(cfg: ModelConfig, params, cache: dict, tokens, position,
                ac: Callable = _Id):
    """One decoding step for the whole stack.

    tokens: (B,) or (B, nq); position: scalar or (B,) int32 write indices
    (per-sequence: continuous-batching slots may be at different depths).
    Returns (logits (B, V) or (B, nq, V), new_cache)."""
    if cfg.n_codebooks > 1:
        x = sum(jnp.take(params["embed"][q], tokens[:, q], axis=0)
                for q in range(cfg.n_codebooks))[:, None]
    else:
        x = jnp.take(params["embed"][0], tokens, axis=0)[:, None]
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    B = x.shape[0]
    position = jnp.broadcast_to(jnp.asarray(position, jnp.int32), (B,))
    windows = jnp.asarray(_layer_windows(cfg))

    def block(x, inp):
        lp, cache_l, is_global = inp
        lp = _cast_layer(lp, jnp.dtype(cfg.compute_dtype))
        new_cache = dict(cache_l)
        if cfg.uses_attention and cfg.family != "ssm":
            h = L.rms_norm(x, lp["attn"]["ln"], cfg.norm_eps)
            a, nk, nv = L.attention_decode(
                lp["attn"], h, cfg, cache_l["k"], cache_l["v"], position,
                window=cfg.sliding_window,
                is_global=(is_global if cfg.global_attn_every > 0 else None))
            new_cache["k"], new_cache["v"] = nk, nv
        if cfg.uses_ssm:
            h = L.rms_norm(x, lp["ssm"]["ln"], cfg.norm_eps)
            s, nconv, nssm = M.mamba_decode(lp["ssm"], h, cfg,
                                            cache_l["conv"], cache_l["ssm"])
            new_cache["conv"], new_cache["ssm"] = nconv, nssm
        if cfg.family == "hybrid":
            x = x + ac(0.5 * (a + s))
        elif cfg.family == "ssm":
            x = x + ac(s)
        else:
            x = x + ac(a)
        if "moe" in lp:
            h = L.rms_norm(x, lp["moe"]["ln"], cfg.norm_eps)
            y, _ = MOE.moe_apply(lp["moe"], h, cfg, ac)
            x = x + ac(y)
        elif "mlp" in lp:
            h = L.rms_norm(x, lp["mlp"]["ln"], cfg.norm_eps)
            x = x + ac(L.mlp_apply(lp["mlp"], h, cfg.mlp))
        return x, new_cache

    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(block, x, (params["layers"], cache,
                                               windows))
    else:
        caches = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            cl = jax.tree.map(lambda a: a[i], cache)
            x, nc = block(x, (lp, cl, windows[i]))
            caches.append(nc)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.n_codebooks > 1:
        lg = jnp.stack([logits_fn(cfg, params, x[:, 0], q)
                        for q in range(cfg.n_codebooks)], axis=1)
    else:
        lg = logits_fn(cfg, params, x[:, 0])
    return lg, new_cache

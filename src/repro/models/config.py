"""Model configuration for the assigned architecture pool.

One ``ModelConfig`` describes any member of the LM family zoo this framework
supports: dense GQA transformers, MoE, Mamba-1 SSMs, hybrid (parallel
attention+SSM) blocks, VLM and audio backbones.  ``src/repro/configs/<id>.py``
instantiates one per assigned architecture.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None      # default: d_model // 16

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank is not None else max(d_model // 16, 1)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    impl: str = "dense"                # "dense" (all-experts) | "ragged" (sorted)
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // n_heads
    mlp: str = "swiglu"                # swiglu | sq_relu | gelu | none
    qk_norm: bool = False
    rope: str = "standard"             # standard | mrope | none
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: layers listed here use full attention; others sliding-window
    sliding_window: Optional[int] = None
    global_attn_every: int = 0         # 0 = all global; k = every k-th layer global
    n_codebooks: int = 1               # musicgen-style multi-codebook heads
    vision_tokens: int = 0             # vlm stub: leading precomputed embeddings
    # numerics / performance knobs (hillclimb levers)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat_policy: str = "full"         # none | full | dots
    scan_layers: bool = True
    seq_shard: bool = False            # sequence/context parallelism on 'model'
    grad_accum: int = 1                # microbatches per step (training)
    pure_dp: bool = False              # small models: fold 'model' into DP
                                       # (TP all-reduces vanish; see §Perf)

    def __post_init__(self):
        families = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")
        if self.family not in families:
            raise ValueError(f"unknown model family {self.family!r}; "
                             f"expected one of {families}")
        if self.family == "moe" and self.moe is None:
            raise ValueError("family='moe' needs a MoEConfig in the `moe` "
                             "field")
        if self.family in ("ssm", "hybrid") and self.ssm is None:
            raise ValueError(f"family={self.family!r} needs an SSMConfig in "
                             f"the `ssm` field")
        if self.n_heads and self.n_kv_heads \
                and self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"n_heads={self.n_heads} must be divisible by "
                f"n_kv_heads={self.n_kv_heads} (GQA groups query heads "
                f"evenly over kv heads)")

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    # ---------------- parameter counting (for roofline MODEL_FLOPS) -------- #
    def param_counts(self) -> dict:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        H, KV = self.n_heads, self.n_kv_heads
        counts = {"embed": V * d * self.n_codebooks, "head": 0 if
                  self.tie_embeddings else V * d * self.n_codebooks,
                  "attn": 0, "mlp": 0, "moe": 0, "moe_active": 0, "ssm": 0}
        L = self.n_layers
        if self.uses_attention:
            counts["attn"] = L * (d * H * hd + 2 * d * KV * hd + H * hd * d)
        if self.mlp != "none" and self.d_ff > 0 and self.family != "moe":
            mult = 3 if self.mlp == "swiglu" else 2
            counts["mlp"] = L * mult * d * ff
        if self.moe:
            eff = self.moe.expert_d_ff
            mult = 3 if self.mlp == "swiglu" else 2
            counts["moe"] = L * self.moe.n_experts * mult * d * eff \
                + L * d * self.moe.n_experts
            counts["moe_active"] = L * self.moe.top_k * mult * d * eff \
                + L * d * self.moe.n_experts
        if self.uses_ssm:
            di = self.d_inner
            N = self.ssm.d_state
            R = self.ssm.resolved_dt_rank(d)
            counts["ssm"] = L * (d * 2 * di + di * self.ssm.d_conv
                                 + di * (R + 2 * N) + R * di + di * N
                                 + 2 * di + di * d)
        return counts

    def n_params(self, active_only: bool = False) -> int:
        c = self.param_counts()
        moe = c["moe_active"] if active_only else c["moe"]
        return c["embed"] + c["head"] + c["attn"] + c["mlp"] + moe + c["ssm"]

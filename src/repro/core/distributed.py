"""Distributed CT projection — the paper's operators at pod scale
(beyond-paper contribution; LEAP itself is single-GPU).

Two orthogonal sharding axes, matching the physics:

* **angle sharding** (data axis): the X-ray transform is a concatenation of
  independent per-view operators, so forward projection is embarrassingly
  parallel over views; the adjoint is a *sum* over views -> one psum.
* **z-slab sharding** (model axis): for parallel beams, axial slabs are
  exactly independent (rays stay in z-planes).  For cone beams a slab's rays
  intersect neighbouring slabs: each shard needs a halo of
  ceil(mag * slab_extent) detector rows; we exchange volume halos with
  ``jax.lax.ppermute`` before projecting (implemented for the common
  one-slab-overlap case; wider cones fall back to angle sharding).

Matched-pair note: adjointness is preserved *per shard* — forward is a
shard-local A followed by gather-of-rows, backward is scatter-of-rows then
shard-local A^T, and the angle psum is the adjoint of replication — so the
distributed pair is still exactly matched (tested in
tests/test_distributed_ct.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.geometry import CTGeometry
from repro.core.spec import ProjectorSpec
from repro.kernels import ops


def _angle_chunks(geom: CTGeometry, n: int):
    assert geom.n_angles % n == 0, \
        f"n_angles {geom.n_angles} must divide angle shards {n}"
    per = geom.n_angles // n
    return [geom.subset(np.arange(i * per, (i + 1) * per)) for i in range(n)]


def make_distributed_projector(geom: CTGeometry, mesh: Mesh,
                               model: str = "sf", backend: str = "auto",
                               angle_axis: str = "data",
                               z_axis: Optional[str] = None,
                               mode: str = "auto"):
    """Returns (fp, bp) callables operating on a volume sharded
    P(None, None, z_axis) and a sinogram sharded P(angle_axis, z_axis, None).

    ``mode`` is forwarded to ``ops.get_ops`` (cone packed-vs-exact kernel
    dispatch — pass ``mode="exact"`` to opt out of the approximate packed
    pair on small-cone-angle geometries).

    Implementation: one ``shard_map``; each shard projects its own angle
    chunk of a (possibly z-slab-sharded) volume with the *local* single-
    device operators (incl. the Pallas kernels).  Parallel and fan beams
    only for z-slab sharding (both have the angle-independent axial overlap,
    hence exact z independence); cone/modular use angle sharding.
    """
    na_shards = int(mesh.shape[angle_axis])
    nz_shards = int(mesh.shape[z_axis]) if z_axis else 1
    if z_axis and geom.geom_type not in ("parallel", "fan"):
        raise NotImplementedError(
            "z-slab sharding requires parallel or fan beam (exact z "
            "independence); shard cone/modular over angles only")
    if z_axis:
        assert geom.vol.nz % nz_shards == 0 and geom.n_rows % nz_shards == 0, \
            "nz and n_rows must divide the z axis"

    chunks = _angle_chunks(geom, na_shards)
    # all chunks have identical shapes; the per-shard geometry differs only
    # in its angle values, which we pass in as data.
    local_geom = chunks[0]
    all_angles = np.stack([c.angles_array() for c in chunks])   # (na_shards, per)

    vol_local = dataclasses.replace(
        geom.vol, nz=geom.vol.nz // nz_shards)
    lgeom = dataclasses.replace(
        local_geom, vol=vol_local, n_rows=geom.n_rows // nz_shards)

    def _local_ops(angles_row):
        g = lgeom.with_angles(np.asarray(angles_row))
        return ops.get_ops(ProjectorSpec(g, model=model, backend=backend,
                                         mode=mode))

    # Geometry must be static: build one jitted op per angle chunk and
    # dispatch on the shard index via lax.switch.
    local_fps = []
    local_bps = []
    for i in range(na_shards):
        fp_i, bp_i = _local_ops(all_angles[i])
        local_fps.append(fp_i)
        local_bps.append(bp_i)

    spec_vol = P(None, None, z_axis)
    spec_sino = P(angle_axis, z_axis, None)

    @partial(compat.shard_map, mesh=mesh, in_specs=(spec_vol,),
             out_specs=spec_sino, check_vma=False)
    def fp(f_local):
        idx = jax.lax.axis_index(angle_axis)
        sino = jax.lax.switch(idx, local_fps, f_local)
        return sino

    @partial(compat.shard_map, mesh=mesh, in_specs=(spec_sino,),
             out_specs=spec_vol, check_vma=False)
    def bp(p_local):
        idx = jax.lax.axis_index(angle_axis)
        vol = jax.lax.switch(idx, local_bps, p_local)
        # adjoint of view-concatenation = sum over view shards
        return jax.lax.psum(vol, angle_axis)

    def shard_volume(f):
        return jax.device_put(f, NamedSharding(mesh, spec_vol))

    def shard_sino(p):
        # reorder global (na, nv, nu) into shard-major angle order
        return jax.device_put(p, NamedSharding(mesh, spec_sino))

    fp.spec_vol, fp.spec_sino = spec_vol, spec_sino  # type: ignore[attr-defined]
    return fp, bp, shard_volume, shard_sino


def halo_exchange_z(f, axis: str, halo: int):
    """Exchange z-halos between neighbouring slab shards (building block for
    cone-beam slab decomposition).  f: (nx, ny, nz_local) inside shard_map.
    Returns f padded to nz_local + 2*halo with neighbours' boundary slices
    (zeros at the fleet edges)."""
    lo = f[:, :, :halo]
    hi = f[:, :, -halo:]
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]
    from_prev = jax.lax.ppermute(hi, axis, fwd)     # neighbour below's top
    from_next = jax.lax.ppermute(lo, axis, bwd)     # neighbour above's bottom
    from_prev = jnp.where(idx == 0, 0.0, from_prev)
    from_next = jnp.where(idx == n - 1, 0.0, from_next)
    return jnp.concatenate([from_prev, f, from_next], axis=2)

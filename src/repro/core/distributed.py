"""Distributed CT projection — the paper's operators at pod scale
(beyond-paper contribution; LEAP itself is single-GPU and PYRO-NN's
TensorFlow operators are per-device).

Two orthogonal sharding axes, matching the physics:

* **angle sharding** (data axis): the X-ray transform is a concatenation of
  independent per-view operators, so forward projection is embarrassingly
  parallel over views; the adjoint is a *sum* over views — an all-reduce
  which the backprojector overlaps with compute (see ``ShardSpec.comm``).
* **z-slab sharding** (model axis): axial slabs of the volume.  Three
  regimes, in increasing generality:

  - *parallel / fan*: slabs are exactly independent (rays stay in
    z-planes), so the slab decomposition is communication-free and the
    halo must be 0.
  - *cone* (circular, source at z=0): detector **row blocks** pair with
    volume slabs; a row block's rays diverge into the neighbour slab by at
    most the magnification overshoot, so each shard projects its slab
    extended by a ``halo`` of voxels exchanged with ``halo_exchange_z``.
  - *modular / helical* (**sliding-z pipeline**): the source travels in z,
    so contiguous **view bands** pair with volume slabs — the mesh-level
    lift of the modular kernel's intra-device sliding-z window.  Each
    shard holds only its slab plus halo; a long-object volume that cannot
    fit in one device's memory reconstructs end to end.

Matched-pair note: forward is ``select-rows ∘ local-A ∘ halo-exchange ∘
broadcast`` per shard; the backprojector is the exact term-by-term adjoint
``psum ∘ halo-reduce ∘ local-Aᵀ ∘ inject-rows`` (``halo_reduce_z`` is the
adjoint of ``halo_exchange_z``, psum the adjoint of broadcast), and the
pair is additionally wired through ``jax.custom_vjp`` — so the distributed
pair is exactly matched and differentiable (tested in
tests/test_distributed_ct.py).

API: build a :class:`~repro.core.spec.ProjectorSpec` with a
:class:`~repro.core.spec.ShardSpec` attached and realize it with
:class:`DistributedProjector` (or the :func:`distribute` convenience).
The pre-spec ``make_distributed_projector`` 4-tuple factory survives as a
once-warning deprecation shim.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core.geometry import CTGeometry
from repro.core.spec import ProjectorSpec, ShardSpec, _warn_legacy
from repro.kernels import ops, tune

__all__ = [
    "ShardSpec",
    "DistributedProjector",
    "distribute",
    "suggest_halo",
    "halo_exchange_z",
    "halo_reduce_z",
    "make_distributed_projector",
]


def _angle_chunks(geom: CTGeometry, n: int) -> List[CTGeometry]:
    if geom.n_angles % n != 0:
        raise ValueError(
            f"n_angles={geom.n_angles} must be divisible by the "
            f"{n} angle shards — pad or subset the scan to a multiple "
            f"(e.g. {geom.n_angles - geom.n_angles % n} views)")
    per = geom.n_angles // n
    return [geom.subset(np.arange(i * per, (i + 1) * per)) for i in range(n)]


# --------------------------------------------------------------------------- #
# z-halo collectives (matched pair: reduce is the exact adjoint of exchange)
# --------------------------------------------------------------------------- #
def halo_exchange_z(f, axis: str, halo: int):
    """Exchange z-halos between neighbouring slab shards.

    ``f``: (nx, ny, nz_local) inside ``shard_map``.  Returns ``f`` extended
    to ``nz_local + 2*halo`` with the neighbours' boundary slices (zeros at
    the fleet edges — the world outside the volume has no voxels).  This is
    the production building block of the cone/modular z-slab paths; its
    exact adjoint is :func:`halo_reduce_z`.
    """
    if halo < 0:
        raise ValueError(f"halo must be >= 0, got {halo}")
    if halo == 0:
        return f
    if halo >= f.shape[2]:
        raise ValueError(
            f"halo={halo} must be smaller than the local slab depth "
            f"nz_local={f.shape[2]} (a halo spanning a whole slab would "
            f"need second-neighbour exchange; use fewer z shards)")
    lo = f[:, :, :halo]
    hi = f[:, :, -halo:]
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]
    from_prev = jax.lax.ppermute(hi, axis, fwd)     # neighbour below's top
    from_next = jax.lax.ppermute(lo, axis, bwd)     # neighbour above's bottom
    from_prev = jnp.where(idx == 0, 0.0, from_prev)
    from_next = jnp.where(idx == n - 1, 0.0, from_next)
    return jnp.concatenate([from_prev, f, from_next], axis=2)


def halo_reduce_z(g, axis: str, halo: int):
    """Exact adjoint of :func:`halo_exchange_z`.

    ``g``: (nx, ny, nz_local + 2*halo) inside ``shard_map`` — a quantity
    accumulated on the halo-extended slab (e.g. a backprojection).  Sends
    each halo slab back to the neighbour that owns those voxels and adds it
    onto their boundary; fleet-edge halos are dropped (they are ghost
    voxels outside the volume).  Returns the owned (nx, ny, nz_local) core.
    """
    if halo < 0:
        raise ValueError(f"halo must be >= 0, got {halo}")
    if halo == 0:
        return g
    if 2 * halo >= g.shape[2]:
        raise ValueError(
            f"halo={halo} inconsistent with extended slab depth "
            f"{g.shape[2]} (needs nz_local = depth - 2*halo >= 1)")
    lo = g[:, :, :halo]                 # contributions to the lower neighbour
    core = g[:, :, halo:-halo]
    hi = g[:, :, -halo:]                # contributions to the upper neighbour
    n = compat.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [((i + 1) % n, i) for i in range(n)]
    from_next = jax.lax.ppermute(lo, axis, bwd)     # neighbour above's lo
    from_prev = jax.lax.ppermute(hi, axis, fwd)     # neighbour below's hi
    from_next = jnp.where(idx == n - 1, 0.0, from_next)
    from_prev = jnp.where(idx == 0, 0.0, from_prev)
    core = core.at[:, :, -halo:].add(from_next)
    core = core.at[:, :, :halo].add(from_prev)
    return core


# --------------------------------------------------------------------------- #
# Halo sizing — conservative world-z extent of a view set's rays
# --------------------------------------------------------------------------- #
def _views_z_extent(geom: CTGeometry, view_idx: np.ndarray,
                    v_lo: float, v_hi: float) -> Tuple[float, float]:
    """Conservative world-z interval touched by the rays of ``view_idx``
    hitting detector rows in ``[v_lo, v_hi]`` (mm, row-coordinate edges).

    Bounds the ray–cylinder chord analytically: with source transaxial
    distance ``|s_xy|``, cylinder radius R, and per-ray transaxial reach
    ``|d_xy|``, the chord parameter lies in ``[(|s_xy|-R)/max|d_xy|,
    (|s_xy|+R)/min|d_xy|]``; z is bilinear in (t, d_z) so corner evaluation
    is exact.  One voxel of margin covers the SF footprint spread.
    """
    vol = geom.vol
    R = vol.radius + max(vol.dx, vol.dz)
    if geom.geom_type == "modular":
        src = np.asarray(geom.source_pos, np.float64)[view_idx]
        ctr = np.asarray(geom.det_center, np.float64)[view_idx]
        eu = np.asarray(geom.det_u, np.float64)[view_idx]
        ev = np.asarray(geom.det_v, np.float64)[view_idx]
    elif geom.geom_type == "cone":
        ang = np.asarray(geom.angles, np.float64)[view_idx]
        c, s = np.cos(ang), np.sin(ang)
        z0 = np.zeros_like(ang)
        src = np.stack([geom.sod * c, geom.sod * s, z0], -1)
        ctr = np.stack([(geom.sod - geom.sdd) * c,
                        (geom.sod - geom.sdd) * s, z0], -1)
        eu = np.stack([-s, c, z0], -1)
        ev = np.stack([z0, z0, np.ones_like(ang)], -1)
    else:
        raise ValueError(
            f"z extent bound only applies to cone/modular geometries, "
            f"got {geom.geom_type!r}")

    u = geom.u_coords()
    u0 = float(u[0]) - geom.pixel_width / 2.0
    u1 = float(u[-1]) + geom.pixel_width / 2.0
    v_abs = max(abs(v_lo), abs(v_hi))

    s_xy = np.hypot(src[:, 0], src[:, 1])
    C = ctr[:, :2] - src[:, :2]                     # transaxial source→center
    E = eu[:, :2]
    ev_xy = np.hypot(ev[:, 0], ev[:, 1])

    def _dxy(uv):
        d = C + uv * E
        return np.hypot(d[:, 0], d[:, 1])

    # |C + uE| over [u0, u1]: convex in u — max at the endpoints, min at the
    # clamped projection u* = -C·E/|E|².
    e2 = np.sum(E * E, axis=1)
    u_star = np.where(e2 > 1e-12, -np.sum(C * E, axis=1) / np.maximum(e2, 1e-12),
                      0.0)
    u_star = np.clip(u_star, u0, u1)
    d_star = np.hypot(C[:, 0] + u_star * E[:, 0], C[:, 1] + u_star * E[:, 1])
    dxy_min = np.minimum(d_star, np.minimum(_dxy(u0), _dxy(u1)))
    dxy_max = np.maximum(_dxy(u0), _dxy(u1))
    # A tilted row axis moves pixels transaxially by up to |v|·|ev_xy|.
    dxy_min = np.maximum(dxy_min - v_abs * ev_xy, 1e-6)
    dxy_max = dxy_max + v_abs * ev_xy

    t_lo = np.maximum(s_xy - R, 0.0) / dxy_max
    t_hi = (s_xy + R) / dxy_min

    # d_z over the (u, v) rectangle: linear, so corner evaluation is exact.
    base = ctr[:, 2] - src[:, 2]
    dz_terms = [base + uu * eu[:, 2] + vv * ev[:, 2]
                for uu in (u0, u1) for vv in (v_lo, v_hi)]
    dz_min = np.minimum.reduce(dz_terms)
    dz_max = np.maximum.reduce(dz_terms)

    cand = [t * d for t in (t_lo, t_hi) for d in (dz_min, dz_max)]
    z_min = np.min(src[:, 2] + np.minimum.reduce(cand)) - vol.dz
    z_max = np.max(src[:, 2] + np.maximum.reduce(cand)) + vol.dz
    return float(z_min), float(z_max)


def suggest_halo(geom: CTGeometry, z_shards: int) -> int:
    """Smallest safe z-halo (voxels) for slab-sharding ``geom`` over
    ``z_shards`` devices: cone pairs detector row blocks with slabs,
    modular/helical pairs contiguous view bands with slabs (the sliding-z
    assignment).  Conservative — derived from the analytic ray-extent bound
    in :func:`_views_z_extent`, clamped to the volume.  Returns 0 for
    parallel/fan (exact slab independence) and for ``z_shards <= 1``.
    """
    if z_shards <= 1 or geom.geom_type in ("parallel", "fan"):
        return 0
    vol = geom.vol
    if vol.nz % z_shards != 0:
        raise ValueError(
            f"vol.nz={vol.nz} must be divisible by z_shards={z_shards}")
    nzl = vol.nz // z_shards
    zc = vol.z_coords()
    dz = vol.dz
    vol_lo, vol_hi = float(zc[0]) - dz / 2, float(zc[-1]) + dz / 2
    v = geom.v_coords()
    dv = geom.pixel_height
    need = 0
    for k in range(z_shards):
        if geom.geom_type == "cone":
            if geom.n_rows % z_shards != 0:
                raise ValueError(
                    f"n_rows={geom.n_rows} must be divisible by "
                    f"z_shards={z_shards} for cone row-block slabs")
            nvl = geom.n_rows // z_shards
            v_lo = float(v[k * nvl]) - dv / 2
            v_hi = float(v[(k + 1) * nvl - 1]) + dv / 2
            idx = np.arange(geom.n_angles)
        else:
            if geom.n_angles % z_shards != 0:
                raise ValueError(
                    f"n_angles={geom.n_angles} must be divisible by "
                    f"z_shards={z_shards} for sliding-z view bands")
            band = geom.n_angles // z_shards
            idx = np.arange(k * band, (k + 1) * band)
            v_lo = float(v[0]) - dv / 2
            v_hi = float(v[-1]) + dv / 2
        z_min, z_max = _views_z_extent(geom, idx, v_lo, v_hi)
        z_min, z_max = max(z_min, vol_lo), min(z_max, vol_hi)
        slab_lo = float(zc[k * nzl]) - dz / 2
        slab_hi = float(zc[(k + 1) * nzl - 1]) + dz / 2
        need = max(need,
                   int(math.ceil(max(slab_lo - z_min, 0.0) / dz)),
                   int(math.ceil(max(z_max - slab_hi, 0.0) / dz)))
    return need


# --------------------------------------------------------------------------- #
# Layout construction
# --------------------------------------------------------------------------- #
def _ext_slab_vol(vol, z_shards: int, k: int, halo: int):
    """The halo-extended slab sub-volume of shard ``k`` — same voxel grid as
    the corresponding world-z window of the global volume (frames and cone
    sources are world-space, so only the volume block changes)."""
    nzl = vol.nz // z_shards
    start = k * nzl - halo
    length = nzl + 2 * halo
    off = (start + (length - 1) / 2.0 - (vol.nz - 1) / 2.0) * vol.dz \
        + vol.offset_z
    return dataclasses.replace(vol, nz=length, offset_z=off)


def _row_block_geom(geom: CTGeometry, z_shards: int, k: int) -> CTGeometry:
    """Geometry restricted to detector row block ``k`` (cone z-slabs)."""
    nvl = geom.n_rows // z_shards
    cr = geom.center_row + geom.pixel_height * (
        k * nvl + (nvl - 1) / 2.0 - (geom.n_rows - 1) / 2.0)
    return dataclasses.replace(geom, n_rows=nvl, center_row=cr)


def _auto_comm_blocks(per: int, lgeom: CTGeometry,
                      config) -> int:
    """Comm granularity for the overlap schedule: the most blocks (<= 4)
    that keep every block a whole number of ``bab`` view-blocks — the BP
    kernels' own view-blocking is the natural unit the reduction can
    overlap."""
    cfg = config if config is not None else tune.heuristic_config(lgeom)
    bab = max(1, cfg.bab or 1)
    for nb in (4, 3, 2):
        if per % nb == 0 and (per // nb) % bab == 0:
            return nb
    return 1


def _validate_mesh(shard: ShardSpec, mesh: Mesh) -> None:
    for ax, n, what in ((shard.angle_axis, shard.angle_shards, "angle"),
                        (shard.z_axis, shard.z_shards, "z")):
        if ax is None:
            continue
        if ax not in mesh.shape:
            raise ValueError(
                f"mesh has no axis {ax!r} (axes: {tuple(mesh.axis_names)}); "
                f"fix ShardSpec.mesh_axes or the mesh")
        if int(mesh.shape[ax]) != n:
            raise ValueError(
                f"ShardSpec.{what}_shards={n} does not match mesh axis "
                f"{ax!r} of size {int(mesh.shape[ax])}")


def _build_distributed(spec: ProjectorSpec, mesh: Mesh):
    """Compile the sharded matched pair for ``spec`` on ``mesh``.

    Returns ``(fp, bp, spec_vol, spec_sino)`` where fp/bp are a
    ``custom_vjp`` matched pair of ``shard_map`` programs.
    """
    shard = spec.shard
    geom = spec.geom
    _validate_mesh(shard, mesh)
    aax, zax = shard.angle_axis, shard.z_axis
    na, nz = shard.angle_shards, shard.z_shards
    halo = shard.halo
    gt = geom.geom_type
    vol = geom.vol

    if nz > 1:
        if vol.nz % nz != 0:
            raise ValueError(
                f"vol.nz={vol.nz} must be divisible by z_shards={nz} "
                f"(pad the volume or change the mesh)")
        nzl = vol.nz // nz
        if gt in ("parallel", "fan"):
            if geom.n_rows % nz != 0:
                raise ValueError(
                    f"n_rows={geom.n_rows} must be divisible by "
                    f"z_shards={nz} for {gt} z-slabs")
            if halo != 0:
                raise ValueError(
                    f"{gt} z-slabs are exactly independent (rays stay in "
                    f"z-planes); halo must be 0, got {halo}")
        elif gt == "cone":
            if geom.n_rows % nz != 0:
                raise ValueError(
                    f"n_rows={geom.n_rows} must be divisible by "
                    f"z_shards={nz} (cone slabs pair with detector row "
                    f"blocks)")
        if gt in ("cone", "modular"):
            need = suggest_halo(geom, nz)
            if need >= nzl:
                raise ValueError(
                    f"{gt} z-slab sharding infeasible: the rays of a "
                    f"shard's {'view band' if gt == 'modular' else 'row block'} "
                    f"span {need} voxels beyond its slab, but the halo must "
                    f"stay below nz_local={nzl}; use fewer z shards "
                    f"(or angle sharding only)")
            if halo < need:
                raise ValueError(
                    f"halo={halo} too small for this geometry: the widest "
                    f"shard's rays reach {need} voxels into the neighbour "
                    f"slab — pass halo>={need} (suggest_halo(geom, "
                    f"z_shards) computes this)")
            if halo >= nzl:
                raise ValueError(
                    f"halo={halo} must be < nz_local={nzl} "
                    f"(single-neighbour exchange)")

    sliding_z = gt == "modular" and nz > 1

    # ---- view assignment + per-shard local geometries -------------------- #
    if sliding_z:
        if geom.n_angles % (na * nz) != 0:
            raise ValueError(
                f"n_angles={geom.n_angles} must be divisible by "
                f"angle_shards*z_shards={na * nz} for the sliding-z "
                f"pipeline (z bands × angle chunks)")
        per = geom.n_angles // (na * nz)
        band = geom.n_angles // nz
        # branch order: flat = iz * na + ia  <->  P((z, angle)) on views
        chunk_geoms = []
        for k in range(nz):
            evol = _ext_slab_vol(vol, nz, k, halo)
            for a in range(na):
                g = geom.subset(np.arange(k * band + a * per,
                                          k * band + (a + 1) * per))
                chunk_geoms.append(dataclasses.replace(g, vol=evol))
        spec_sino = P((zax, aax), None, None)
    else:
        chunks = _angle_chunks(geom, na)
        per = geom.n_angles // na
        if nz > 1 and gt == "cone":
            chunk_geoms = []
            for k in range(nz):
                evol = _ext_slab_vol(vol, nz, k, halo)
                for a in range(na):
                    g = _row_block_geom(chunks[a], nz, k)
                    chunk_geoms.append(dataclasses.replace(g, vol=evol))
        elif nz > 1:
            # parallel/fan: slabs are translation-invariant in z — one op
            # per angle chunk serves every slab shard.
            vol_local = dataclasses.replace(vol, nz=vol.nz // nz)
            chunk_geoms = [
                dataclasses.replace(c, vol=vol_local,
                                    n_rows=geom.n_rows // nz)
                for c in chunks]
        else:
            chunk_geoms = chunks
        spec_sino = P(aax, zax, None)
    spec_vol = P(None, None, zax)
    z_branched = sliding_z or (nz > 1 and gt == "cone")

    # ---- local op bundles ------------------------------------------------ #
    def _local_ops(g: CTGeometry):
        return ops.get_ops(spec.replace(geom=g, shard=None))

    local_fps = [_local_ops(g)[0] for g in chunk_geoms]

    if shard.comm == "psum":
        nb = max(1, shard.comm_blocks) if shard.comm_blocks else 1
    else:
        nb = shard.comm_blocks or _auto_comm_blocks(per, chunk_geoms[0],
                                                    spec.config)
    if per % nb != 0:
        raise ValueError(
            f"comm_blocks={nb} must divide the per-shard view count {per}")
    blk = per // nb
    if nb == 1:
        local_bps = [[_local_ops(g)[1] for g in chunk_geoms]]
    else:
        local_bps = [
            [_local_ops(g.subset(np.arange(b * blk, (b + 1) * blk)))[1]
             for g in chunk_geoms]
            for b in range(nb)]

    def _flat_idx():
        ia = jax.lax.axis_index(aax)
        if z_branched:
            return jax.lax.axis_index(zax) * na + ia
        return ia

    use_halo = halo > 0 and nz > 1

    @partial(compat.shard_map, mesh=mesh, in_specs=(spec_vol,),
             out_specs=spec_sino, check_vma=False)
    def _fp(f_local):
        x = halo_exchange_z(f_local, zax, halo) if use_halo else f_local
        return jax.lax.switch(_flat_idx(), local_fps, x)

    @partial(compat.shard_map, mesh=mesh, in_specs=(spec_sino,),
             out_specs=spec_vol, check_vma=False)
    def _bp(p_local):
        idx = _flat_idx()
        # Overlap-communication schedule: one psum per comm block, issued
        # between the per-block Pallas backprojections — block b's
        # all-reduce is independent of block b+1's compute, so the XLA
        # async collectives hide the reduction behind the kernels.  With
        # comm="psum" (nb=1) this degenerates to the legacy synchronous
        # single psum after the whole local backprojection.
        acc = None
        for b in range(nb):
            pb = p_local[b * blk:(b + 1) * blk] if nb > 1 else p_local
            part = jax.lax.switch(idx, local_bps[b], pb)
            part = jax.lax.psum(part, aax)
            acc = part if acc is None else acc + part
        if use_halo:
            acc = halo_reduce_z(acc, zax, halo)
        return acc

    # jit *inside* the custom_vjp pair: an eager shard_map re-traces the
    # whole mesh program on every call, which dominates any real workload.
    fp, bp = ops._make_pair(jax.jit(_fp), jax.jit(_bp))
    return fp, bp, spec_vol, spec_sino


# --------------------------------------------------------------------------- #
# Public objects
# --------------------------------------------------------------------------- #
class DistributedProjector:
    """A matched differentiable projector pair laid out on a device mesh.

    Built from a :class:`ProjectorSpec` with a :class:`ShardSpec` attached::

        spec = ProjectorSpec(geom, shard=ShardSpec(("data", "model"),
                                                   angle_shards=4,
                                                   z_shards=2, halo=2))
        dp = DistributedProjector(spec, mesh)
        sino = dp(dp.shard_volume(f))       # A x, sharded
        vol  = dp.T(sino)                   # A^T y, sharded

    The object quacks like :class:`~repro.core.projector.Projector` — the
    iterative solvers (``sirt``/``cgls``/``fista_tv``) accept it directly,
    so distributed reconstruction needs no solver forks.
    """

    def __init__(self, spec: ProjectorSpec, mesh: Mesh):
        if not isinstance(spec, ProjectorSpec):
            raise TypeError(
                f"DistributedProjector needs a ProjectorSpec, got "
                f"{type(spec).__name__} (legacy geometry-first callers: "
                f"use make_distributed_projector or build a spec)")
        if spec.shard is None:
            raise ValueError(
                "spec has no ShardSpec attached; pass "
                "ProjectorSpec(geom, ..., shard=ShardSpec(...)) or use "
                "distribute(spec, mesh, ...)")
        self.spec = spec
        self.mesh = mesh
        self.fp, self.bp, self._spec_vol, self._spec_sino = \
            _build_distributed(spec, mesh)

    # -- Projector-compatible surface -------------------------------------- #
    @property
    def geom(self) -> CTGeometry:
        return self.spec.geom

    @property
    def shard(self) -> ShardSpec:
        return self.spec.shard

    def __call__(self, volume):
        return self.fp(volume)

    forward = __call__

    def backproject(self, sino):
        return self.bp(sino)

    @property
    def T(self):
        return self.backproject

    def vol_shape(self):
        return self.geom.vol.shape

    def sino_shape(self):
        return self.geom.sino_shape

    def data_consistency(self, volume, measured, mask=None):
        """0.5 * || M (A x - y) ||^2 / n with the sharded operator."""
        r = self(volume) - measured
        if mask is not None:
            r = r * mask
        return 0.5 * jnp.mean(jnp.square(r))

    # -- placement helpers -------------------------------------------------- #
    def shard_volume(self, f):
        """Place a global (nx, ny, nz) volume in the mesh layout."""
        return jax.device_put(f, NamedSharding(self.mesh, self._spec_vol))

    def shard_sino(self, p):
        """Place a global (n_angles, n_rows, n_cols) sinogram in the mesh
        layout (views z-band-major for the sliding-z pipeline)."""
        return jax.device_put(p, NamedSharding(self.mesh, self._spec_sino))

    def __repr__(self):
        s = self.shard
        return (f"DistributedProjector({self.geom.geom_type}, "
                f"angle_shards={s.angle_shards}, z_shards={s.z_shards}, "
                f"halo={s.halo}, comm={s.comm}, vol={self.geom.vol.shape}, "
                f"sino={self.geom.sino_shape})")


def distribute(spec: ProjectorSpec, mesh: Mesh, *,
               angle_axis: str = "data", z_axis: Optional[str] = None,
               halo: Optional[int] = None, comm: str = "overlap",
               comm_blocks: int = 0) -> DistributedProjector:
    """Attach a mesh-derived :class:`ShardSpec` to ``spec`` and build the
    :class:`DistributedProjector`.

    ``halo=None`` sizes the z-halo automatically via :func:`suggest_halo`
    (0 for parallel/fan).  A spec that already carries a shard passes
    through unchanged (mixing it with layout kwargs raises).
    """
    if not isinstance(spec, ProjectorSpec):
        raise TypeError(
            f"distribute() needs a ProjectorSpec, got "
            f"{type(spec).__name__}")
    if spec.shard is not None:
        if (angle_axis, z_axis, halo, comm, comm_blocks) != \
                ("data", None, None, "overlap", 0):
            raise TypeError(
                "distribute(): pass either a spec with a ShardSpec or "
                "layout kwargs, not both")
        return DistributedProjector(spec, mesh)
    z_shards = int(mesh.shape[z_axis]) if z_axis else 1
    if halo is None:
        halo = suggest_halo(spec.geom, z_shards)
    shard = ShardSpec(mesh_axes=(angle_axis, z_axis),
                      angle_shards=int(mesh.shape[angle_axis]),
                      z_shards=z_shards, halo=halo, comm=comm,
                      comm_blocks=comm_blocks)
    return DistributedProjector(spec.replace(shard=shard), mesh)


# --------------------------------------------------------------------------- #
# Legacy-call-site shim (pre-ShardSpec 4-tuple factory)
# --------------------------------------------------------------------------- #
def make_distributed_projector(geom: CTGeometry, mesh: Mesh,
                               model: str = "sf", backend: str = "auto",
                               angle_axis: str = "data",
                               z_axis: Optional[str] = None,
                               mode: str = "auto"):
    """Deprecated 4-tuple factory — returns ``(fp, bp, shard_volume,
    shard_sino)`` exactly as before the ShardSpec redesign (same
    synchronous-psum schedule, bit-exact on the old call shape).  Build a
    ``ProjectorSpec`` with a ``ShardSpec`` and use
    :class:`DistributedProjector` instead; warns once per process.
    """
    _warn_legacy("make_distributed_projector")
    if z_axis and geom.geom_type not in ("parallel", "fan"):
        raise NotImplementedError(
            "z-slab sharding requires parallel or fan beam (exact z "
            "independence) through this legacy factory; cone/modular "
            "z-slabs need a halo — use DistributedProjector with "
            "ShardSpec(halo=suggest_halo(geom, z_shards))")
    shard = ShardSpec(mesh_axes=(angle_axis, z_axis),
                      angle_shards=int(mesh.shape[angle_axis]),
                      z_shards=int(mesh.shape[z_axis]) if z_axis else 1,
                      halo=0, comm="psum", comm_blocks=1)
    spec = ProjectorSpec(geom, model=model, backend=backend, mode=mode,
                         shard=shard)
    dp = DistributedProjector(spec, mesh)
    return dp.fp, dp.bp, dp.shard_volume, dp.shard_sino

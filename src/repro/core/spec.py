"""``ProjectorSpec`` — the single immutable description of a projection op.

Before this module the projector stack passed five loose keyword arguments
``(model, backend, mode, compute_dtype, config)`` alongside every geometry,
and the op cache in :mod:`repro.kernels.ops` re-assembled them into an
ad-hoc tuple key at every call site.  ``ProjectorSpec`` consolidates the
whole configuration into one frozen, hashable value:

    >>> spec = ProjectorSpec(geom, model="sf", compute_dtype="bf16")
    >>> proj = Projector(spec)
    >>> fp, bp = get_ops(spec)
    >>> sino = forward_project(f, spec)

The spec is simultaneously

  * the **op-cache key** (``spec.cache_key()`` replaces the old tuple key),
  * the **serving admission-bucket key** (``spec.bucket_key()``): two recon
    requests may share a dynamically packed batch iff their specs hash
    equal — same geometry content, same kernels, same precision — so one
    compiled executable serves them all, and
  * the **validation point**: bad model/mode/backend/dtype values raise here,
    once, instead of deep inside dispatch.

Legacy geometry-first call sites (``Projector(geom, model=...)``,
``forward_project(f, geom, ...)``) keep working through :func:`as_spec`,
which emits a single :class:`DeprecationWarning` per entry point per
process and builds the equivalent spec.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from typing import Optional, Tuple, TYPE_CHECKING

from repro.core.geometry import CTGeometry

if TYPE_CHECKING:                                     # pragma: no cover
    from repro.kernels.tune import KernelConfig

__all__ = ["ProjectorSpec", "ShardSpec", "as_spec", "reset_legacy_warnings"]

_MODELS = ("sf", "joseph")
_BACKENDS = ("auto", "pallas", "ref")
_MODES = ("auto", "exact", "packed")
_COMMS = ("overlap", "psum")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """Frozen description of how a projection operator is laid out on a mesh.

    The shard layout is part of the *operator identity*: two distributed
    projectors with different layouts compile different programs, exchange
    different halos, and must not share op-cache entries or serving buckets,
    so ``ShardSpec`` participates in ``ProjectorSpec.cache_key()`` /
    ``bucket_key()`` exactly like ``model`` or ``compute_dtype``.

    Fields:
        mesh_axes:     ``(angle_axis, z_axis)`` mesh-axis names.  ``z_axis``
                       may be ``None`` when ``z_shards == 1`` (pure angle
                       sharding).
        angle_shards:  shards along the view/angle axis (the data axis of
                       the X-ray transform — views are independent in the
                       forward direction, summed in the adjoint).
        z_shards:      shards along the volume z axis (the model axis —
                       axial slabs).
        halo:          z-slab halo width in voxels exchanged between
                       neighbouring slabs (``jax.lax.ppermute``).  Must be 0
                       for parallel/fan (their slabs are exactly
                       independent) and positive for cone/modular z-slabs
                       (diverging / z-travelling rays read into the
                       neighbour slab).
        comm:          backprojection reduction schedule — ``"overlap"``
                       (default) splits the local views into comm blocks and
                       issues one psum per block so the collective for block
                       *b* overlaps the Pallas BP of block *b+1*;
                       ``"psum"`` is the legacy single synchronous psum
                       after all local views are backprojected.
        comm_blocks:   number of comm blocks for ``comm="overlap"``; 0 means
                       auto (largest divisor of the per-shard view count
                       that is <= 4, aligned with the kernels' ``bab``
                       view-blocking granularity).
    """

    mesh_axes: Tuple[Optional[str], ...] = ("data", "model")
    angle_shards: int = 1
    z_shards: int = 1
    halo: int = 0
    comm: str = "overlap"
    comm_blocks: int = 0

    def __post_init__(self):
        axes = tuple(self.mesh_axes)
        if len(axes) != 2:
            raise ValueError(
                f"mesh_axes must be (angle_axis, z_axis), got {axes!r}")
        if not isinstance(axes[0], str) or not axes[0]:
            raise ValueError(
                f"angle axis (mesh_axes[0]) must be a mesh-axis name, "
                f"got {axes[0]!r}")
        if axes[1] is not None and (not isinstance(axes[1], str)
                                    or axes[1] == axes[0]):
            raise ValueError(
                f"z axis (mesh_axes[1]) must be None or a mesh-axis name "
                f"distinct from the angle axis, got {axes!r}")
        object.__setattr__(self, "mesh_axes", axes)
        if self.angle_shards < 1 or self.z_shards < 1:
            raise ValueError(
                f"angle_shards/z_shards must be >= 1, got "
                f"{(self.angle_shards, self.z_shards)}")
        if self.z_shards > 1 and axes[1] is None:
            raise ValueError(
                f"z_shards={self.z_shards} needs a z mesh axis "
                f"(mesh_axes[1] is None)")
        if self.halo < 0:
            raise ValueError(f"halo must be >= 0, got {self.halo}")
        if self.z_shards == 1 and self.halo != 0:
            raise ValueError(
                f"halo={self.halo} is meaningless with z_shards=1; "
                f"set halo=0")
        if self.comm not in _COMMS:
            raise ValueError(f"unknown comm schedule {self.comm!r}; "
                             f"expected one of {_COMMS}")
        if self.comm_blocks < 0:
            raise ValueError(
                f"comm_blocks must be >= 0 (0 = auto), got {self.comm_blocks}")

    @property
    def angle_axis(self) -> str:
        return self.mesh_axes[0]

    @property
    def z_axis(self) -> Optional[str]:
        return self.mesh_axes[1]

    def replace(self, **kw) -> "ShardSpec":
        return dataclasses.replace(self, **kw)

    def _identity(self) -> Tuple:
        return (self.mesh_axes, self.angle_shards, self.z_shards, self.halo,
                self.comm, self.comm_blocks)


@dataclasses.dataclass(frozen=True, eq=False)
class ProjectorSpec:
    """Frozen, hashable description of one projection operator.

    Fields:
        geom:          scanner geometry (content-hashed — two specs built
                       from equal geometries compare/hash equal even when
                       the geometry objects differ).
        model:         footprint model, ``"sf"`` | ``"joseph"``.
        backend:       ``"auto"`` | ``"pallas"`` | ``"ref"``.
        mode:          packed-kernel policy, ``"auto"`` | ``"exact"`` |
                       ``"packed"`` (cone only; see kernels/ops.py).
        compute_dtype: kernel tile precision, ``"bfloat16"`` | ``"float32"``
                       | None (follow the input dtype); aliases like
                       ``"bf16"`` are canonicalized at construction.
        config:        explicit :class:`~repro.kernels.tune.KernelConfig`
                       pin, or None to let the registry/autotuner resolve.
        shard:         :class:`ShardSpec` describing a multi-device layout,
                       or None for a single-device operator.  A spec with a
                       shard attached must be realized through
                       :class:`repro.core.distributed.DistributedProjector`
                       — the local op cache rejects it (the shard layout
                       changes the compiled program, the collectives, and
                       the halo wiring, none of which a local bundle
                       carries).
    """

    geom: CTGeometry
    model: str = "sf"
    backend: str = "auto"
    mode: str = "auto"
    compute_dtype: Optional[str] = None
    config: Optional["KernelConfig"] = None
    shard: Optional[ShardSpec] = None

    def __post_init__(self):
        # Late imports: repro.kernels imports this module at its top level
        # (ops.py), so the kernels package cannot be imported here eagerly.
        from repro.kernels import precision
        from repro.kernels.tune import KernelConfig
        if not isinstance(self.geom, CTGeometry):
            raise TypeError(
                f"ProjectorSpec.geom must be a CTGeometry, got {self.geom!r}")
        if self.model not in _MODELS:
            raise ValueError(f"unknown projector model {self.model!r}")
        if self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; expected "
                             f"one of {_BACKENDS}")
        if self.mode not in _MODES:
            raise ValueError(f"unknown mode {self.mode!r}; expected "
                             f"'auto', 'exact' or 'packed'")
        if self.config is not None and not isinstance(self.config, KernelConfig):
            raise TypeError(f"config must be a KernelConfig, "
                            f"got {self.config!r}")
        if self.shard is not None and not isinstance(self.shard, ShardSpec):
            raise TypeError(f"shard must be a ShardSpec, got {self.shard!r}")
        # Validates eagerly (raises ValueError on junk) and canonicalizes
        # aliases ("bf16" -> "bfloat16") so the cache key is stable.
        object.__setattr__(self, "compute_dtype",
                           precision.normalize(self.compute_dtype))

    def replace(self, **kw) -> "ProjectorSpec":
        return dataclasses.replace(self, **kw)

    # -- identity ----------------------------------------------------------- #
    def _identity(self) -> Tuple:
        """Content identity: geometry by canonical hash, the rest by value."""
        return (self.geom.canonical_hash(), self.model, self.backend,
                self.mode, self.compute_dtype, self.config,
                None if self.shard is None else self.shard._identity())

    def __eq__(self, other):
        if not isinstance(other, ProjectorSpec):
            return NotImplemented
        return self._identity() == other._identity()

    def __hash__(self):
        return hash(self._identity())

    # -- keys --------------------------------------------------------------- #
    def cache_key(self, resolved_mode: Optional[str] = None,
                  in_dtype: Optional[str] = None) -> Tuple:
        """The op-cache key (replaces the old ad-hoc tuple in ops.py).

        ``resolved_mode`` is the concrete pair dispatch would pick
        ("exact" | "packed") so that ``mode="auto"`` and an explicit
        equivalent share one bundle; ``in_dtype`` is the dtype name of the
        array the ops are first applied to (a ``compute_dtype=None`` bundle
        follows its input's dtype, so f32 and bf16 callers must not share
        traced closures)."""
        return (self.geom.canonical_hash(), self.model, self.backend,
                self.config, resolved_mode or self.mode, self.compute_dtype,
                in_dtype,
                None if self.shard is None else self.shard._identity())

    def bucket_key(self) -> str:
        """Short stable digest for serving admission: requests whose specs
        share this key are compatible for dynamic batch packing (identical
        geometry content, kernels, mode policy, and precision — one compiled
        executable covers the packed batch)."""
        cfg = (None if self.config is None
               else sorted(dataclasses.asdict(self.config).items()))
        shard = (None if self.shard is None
                 else sorted(dataclasses.asdict(self.shard).items(),
                             key=lambda kv: kv[0]))
        payload = json.dumps(
            [self.geom.canonical_hash(), self.model, self.backend,
             self.mode, self.compute_dtype, cfg, shard])
        return hashlib.sha256(payload.encode()).hexdigest()[:16]

    def __repr__(self):
        g = self.geom
        extras = []
        if self.mode != "auto":
            extras.append(f"mode={self.mode}")
        if self.compute_dtype is not None:
            extras.append(f"compute_dtype={self.compute_dtype}")
        if self.config is not None:
            extras.append(f"config={self.config}")
        if self.shard is not None:
            extras.append(f"shard={self.shard}")
        tail = (", " + ", ".join(extras)) if extras else ""
        return (f"ProjectorSpec({g.geom_type}, model={self.model}, "
                f"backend={self.backend}{tail}, vol={g.vol.shape}, "
                f"sino={g.sino_shape})")


# --------------------------------------------------------------------------- #
# Legacy-call-site shim
# --------------------------------------------------------------------------- #
_DEFAULTS = ("sf", "auto", "auto", None, None)
_WARNED: set = set()


def _warn_legacy(api: str) -> None:
    if api in _WARNED:
        return
    _WARNED.add(api)
    warnings.warn(
        f"{api} with geometry-first arguments is deprecated; build a "
        f"ProjectorSpec once and pass it instead, e.g. "
        f"spec = ProjectorSpec(geom, model=..., backend=...); {api}(spec). "
        f"(warned once per process)",
        DeprecationWarning, stacklevel=4)


def reset_legacy_warnings() -> None:
    """Forget which entry points already warned (test hook)."""
    _WARNED.clear()


def as_spec(spec_or_geom, api: str, model: str = "sf", backend: str = "auto",
            mode: str = "auto", compute_dtype=None,
            config=None) -> ProjectorSpec:
    """Coerce an entry point's first argument to a :class:`ProjectorSpec`.

    A spec passes through unchanged (mixing it with legacy keyword arguments
    is ambiguous and raises); a :class:`CTGeometry` takes the legacy path —
    one :class:`DeprecationWarning` per ``api`` per process, then the
    equivalent spec, so pre-redesign call sites behave identically."""
    if isinstance(spec_or_geom, ProjectorSpec):
        if (model, backend, mode, compute_dtype, config) != _DEFAULTS:
            raise TypeError(
                f"{api}: pass either a ProjectorSpec or legacy keyword "
                f"arguments, not both (got spec plus non-default kwargs)")
        return spec_or_geom
    if isinstance(spec_or_geom, CTGeometry):
        _warn_legacy(api)
        return ProjectorSpec(spec_or_geom, model=model, backend=backend,
                             mode=mode, compute_dtype=compute_dtype,
                             config=config)
    raise TypeError(f"{api}: expected a ProjectorSpec or CTGeometry, "
                    f"got {type(spec_or_geom).__name__}")

"""The ``Projector`` module — the library's main user-facing class.

This is the JAX analogue of the paper's ``torch.nn.Module``-derived
``Projector`` (their Listing 1): a differentiable object that can be dropped
into any training/inference pipeline.

    >>> spec = ProjectorSpec(geom)             # frozen op description
    >>> proj = Projector(spec)                 # (legacy Projector(geom, ...)
    ...                                        #  still works via the shim)
    >>> sino = proj(volume)                    # A x        (differentiable)
    >>> vol  = proj.backproject(sino)          # A^T y      (differentiable)
    >>> rec  = proj.fbp(sino)                  # filtered backprojection
    >>> loss = proj.data_consistency(volume, measured)   # ||Ax - y||^2 term

Batched inputs (leading dims) are supported; gradients flow through every
method via the matched custom_vjp pairs in ``repro.kernels.ops``.  On the
Pallas backend every geometry (parallel, fan, cone, and axial-frame
modular — incl. helical scans) runs a kernel matched pair — the
backprojection (and therefore every gradient) is the exact transpose of
the forward kernel, never a fallback adjoint.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp

from repro.core.fbp import fbp as _fbp
from repro.core.geometry import CTGeometry
from repro.core.spec import ProjectorSpec, as_spec
from repro.kernels import ops
from repro.kernels.tune import KernelConfig


class Projector:
    def __init__(self, spec_or_geom, model: str = "sf",
                 backend: str = "auto",
                 config: Optional[KernelConfig] = None,
                 mode: str = "auto", compute_dtype=None):
        """Canonical form: ``Projector(ProjectorSpec(geom, ...))`` — the
        spec is the single frozen description of the operator and doubles
        as the op-cache / serving-bucket key.  The legacy geometry-first
        form (``Projector(geom, model=..., mode=...)``) keeps working via
        the deprecation shim in :mod:`repro.core.spec`.

        ``mode`` selects between the exact kernels and the approximate
        lane-packed cone pair: "exact" always uses the exact kernels,
        "packed" forces the packed pair (small-cone-angle pre-resample),
        "auto" (default) uses packed only when the geometry's derived error
        bound is under tolerance (see ``repro.kernels.tune.packed_cone_ok``).
        Non-cone geometries are unaffected by ``mode``.

        ``compute_dtype`` sets the kernel tile precision ("bfloat16" |
        "float32"; None follows the input dtype): tiles stream at that
        dtype, accumulation stays f32, outputs keep the input's dtype —
        see kernels/precision.py for the policy and its tolerance model.

        Modular geometries run the SF matched pair like every other
        geometry (Pallas for axial frames — incl. helical — via the
        registered `supports` gate); tilted frames fall back to the Joseph
        ray-marcher inside the ref dispatch, so "sf" is always safe here."""
        self.spec = as_spec(spec_or_geom, "Projector", model=model,
                            backend=backend, mode=mode,
                            compute_dtype=compute_dtype, config=config)

    # Back-compat attribute surface: pre-spec code read these directly.
    @property
    def geom(self) -> CTGeometry:
        return self.spec.geom

    @property
    def model(self) -> str:
        return self.spec.model

    @property
    def backend(self) -> str:
        return self.spec.backend

    @property
    def config(self) -> Optional[KernelConfig]:
        return self.spec.config

    @property
    def mode(self) -> str:
        return self.spec.mode

    @property
    def compute_dtype(self):
        return self.spec.compute_dtype

    @classmethod
    def from_model_config(cls, geom: CTGeometry, model_config, **kwargs):
        """Build a projector honoring a ``models.config.ModelConfig``: its
        ``compute_dtype`` (the field the LM stack already applies to its
        matmuls) becomes the kernel tile precision, so a reconstruction
        head shares one precision policy with the model around it."""
        kwargs.setdefault("compute_dtype",
                          getattr(model_config, "compute_dtype", None))
        return cls(ProjectorSpec(geom, **kwargs))

    # -- linear ops -------------------------------------------------------- #
    def __call__(self, volume):
        return ops.forward_project(volume, self.spec)

    forward = __call__

    def backproject(self, sino):
        return ops.back_project(sino, self.spec)

    @property
    def T(self):
        return self.backproject

    # -- analytic reconstruction ------------------------------------------ #
    def fbp(self, sino, filter_name: str = "ramp",
            short_scan: Optional[bool] = None):
        """``short_scan`` applies Parker weighting for fan beams (``None``
        auto-detects from the geometry's angular span)."""
        op = functools.partial(_fbp, geom=self.geom, model=self.model,
                               backend=self.backend, filter_name=filter_name,
                               config=self.config, short_scan=short_scan)
        return ops._batched(op, sino, 3)

    # -- DL integration ---------------------------------------------------- #
    def data_consistency(self, volume, measured, mask=None):
        """0.5 * || M (A x - y) ||^2 / n  — the paper's data-consistency loss.

        ``mask`` selects measured views/pixels (limited-angle / few-view)."""
        r = self(volume) - measured
        if mask is not None:
            r = r * mask
        return 0.5 * jnp.mean(jnp.square(r))

    def complete_sinogram(self, volume, measured, mask):
        """Sinogram completion (paper §3): keep measured views, fill the rest
        from the forward projection of the predicted volume."""
        synth = self(volume)
        return mask * measured + (1.0 - mask) * synth

    # -- misc --------------------------------------------------------------- #
    def sino_shape(self):
        return self.geom.sino_shape

    def vol_shape(self):
        return self.geom.vol.shape

    def __repr__(self):
        g = self.geom
        mode = f", mode={self.mode}" if self.mode != "auto" else ""
        cdt = (f", compute_dtype={self.compute_dtype}"
               if self.compute_dtype is not None else "")
        return (f"Projector({g.geom_type}, model={self.model}{mode}{cdt}, "
                f"vol={g.vol.shape}, sino={g.sino_shape})")

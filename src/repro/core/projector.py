"""The ``Projector`` module — the library's main user-facing class.

This is the JAX analogue of the paper's ``torch.nn.Module``-derived
``Projector`` (their Listing 1): a differentiable object that can be dropped
into any training/inference pipeline.

    >>> proj = Projector(geom)                 # geometry = static metadata
    >>> sino = proj(volume)                    # A x        (differentiable)
    >>> vol  = proj.backproject(sino)          # A^T y      (differentiable)
    >>> rec  = proj.fbp(sino)                  # filtered backprojection
    >>> loss = proj.data_consistency(volume, measured)   # ||Ax - y||^2 term

Batched inputs (leading dims) are supported; gradients flow through every
method via the matched custom_vjp pairs in ``repro.kernels.ops``.  On the
Pallas backend every geometry (parallel, fan, cone, and axial-frame
modular — incl. helical scans) runs a kernel matched pair — the
backprojection (and therefore every gradient) is the exact transpose of
the forward kernel, never a fallback adjoint.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax.numpy as jnp

from repro.core.fbp import fbp as _fbp
from repro.core.geometry import CTGeometry
from repro.kernels import ops, precision
from repro.kernels.tune import KernelConfig


class Projector:
    def __init__(self, geom: CTGeometry, model: str = "sf",
                 backend: str = "auto",
                 config: Optional[KernelConfig] = None,
                 mode: str = "auto", compute_dtype=None):
        """``mode`` selects between the exact kernels and the approximate
        lane-packed cone pair: "exact" always uses the exact kernels,
        "packed" forces the packed pair (small-cone-angle pre-resample),
        "auto" (default) uses packed only when the geometry's derived error
        bound is under tolerance (see ``repro.kernels.tune.packed_cone_ok``).
        Non-cone geometries are unaffected by ``mode``.

        ``compute_dtype`` sets the kernel tile precision ("bfloat16" |
        "float32"; None follows the input dtype): tiles stream at that
        dtype, accumulation stays f32, outputs keep the input's dtype —
        see kernels/precision.py for the policy and its tolerance model."""
        if model not in ("sf", "joseph"):
            raise ValueError(f"unknown projector model {model!r}")
        if mode not in ("auto", "exact", "packed"):
            raise ValueError(f"unknown mode {mode!r}; expected "
                             f"'auto', 'exact' or 'packed'")
        if config is not None and not isinstance(config, KernelConfig):
            raise TypeError(f"config must be a KernelConfig, got {config!r}")
        self.geom = geom
        # Modular geometries run the SF matched pair like every other
        # geometry now (Pallas for axial frames — incl. helical — via the
        # registered `supports` gate); tilted frames fall back to the Joseph
        # ray-marcher inside the ref dispatch, so "sf" is always safe here.
        self.model = model
        self.backend = backend
        self.config = config
        self.mode = mode
        # Validates eagerly (raises ValueError on junk) and canonicalizes
        # aliases ("bf16" -> "bfloat16") so the op-cache key is stable.
        self.compute_dtype = precision.normalize(compute_dtype)

    @classmethod
    def from_model_config(cls, geom: CTGeometry, model_config, **kwargs):
        """Build a projector honoring a ``models.config.ModelConfig``: its
        ``compute_dtype`` (the field the LM stack already applies to its
        matmuls) becomes the kernel tile precision, so a reconstruction
        head shares one precision policy with the model around it."""
        kwargs.setdefault("compute_dtype",
                          getattr(model_config, "compute_dtype", None))
        return cls(geom, **kwargs)

    # -- linear ops -------------------------------------------------------- #
    def __call__(self, volume):
        return ops.forward_project(volume, self.geom, self.model,
                                   self.backend, self.config, self.mode,
                                   self.compute_dtype)

    forward = __call__

    def backproject(self, sino):
        return ops.back_project(sino, self.geom, self.model, self.backend,
                                self.config, self.mode, self.compute_dtype)

    @property
    def T(self):
        return self.backproject

    # -- analytic reconstruction ------------------------------------------ #
    def fbp(self, sino, filter_name: str = "ramp",
            short_scan: Optional[bool] = None):
        """``short_scan`` applies Parker weighting for fan beams (``None``
        auto-detects from the geometry's angular span)."""
        op = functools.partial(_fbp, geom=self.geom, model=self.model,
                               backend=self.backend, filter_name=filter_name,
                               config=self.config, short_scan=short_scan)
        return ops._batched(op, sino, 3)

    # -- DL integration ---------------------------------------------------- #
    def data_consistency(self, volume, measured, mask=None):
        """0.5 * || M (A x - y) ||^2 / n  — the paper's data-consistency loss.

        ``mask`` selects measured views/pixels (limited-angle / few-view)."""
        r = self(volume) - measured
        if mask is not None:
            r = r * mask
        return 0.5 * jnp.mean(jnp.square(r))

    def complete_sinogram(self, volume, measured, mask):
        """Sinogram completion (paper §3): keep measured views, fill the rest
        from the forward projection of the predicted volume."""
        synth = self(volume)
        return mask * measured + (1.0 - mask) * synth

    # -- misc --------------------------------------------------------------- #
    def sino_shape(self):
        return self.geom.sino_shape

    def vol_shape(self):
        return self.geom.vol.shape

    def __repr__(self):
        g = self.geom
        mode = f", mode={self.mode}" if self.mode != "auto" else ""
        cdt = (f", compute_dtype={self.compute_dtype}"
               if self.compute_dtype is not None else "")
        return (f"Projector({g.geom_type}, model={self.model}{mode}{cdt}, "
                f"vol={g.vol.shape}, sino={g.sino_shape})")

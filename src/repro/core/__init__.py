"""Core library: the paper's contribution — differentiable CT projectors.

Public API:
    CTGeometry / VolumeGeometry / parallel_beam / cone_beam / modular_beam
    Projector            — differentiable forward/back projection module
    forward_project / back_project — functional matched-pair ops
    fbp                  — filtered backprojection / FDK

The projector/ops re-exports are lazy to keep `repro.core` importable from
inside `repro.kernels` (the kernels register themselves with ops at import).
"""
from repro.core.geometry import (CTGeometry, VolumeGeometry, cone_beam,
                                 fan_beam, from_config, helical_beam,
                                 modular_beam, parallel_beam)
from repro.core.spec import ProjectorSpec, ShardSpec

__all__ = [
    "CTGeometry", "VolumeGeometry", "parallel_beam", "fan_beam", "cone_beam",
    "modular_beam", "helical_beam", "from_config", "Projector",
    "ProjectorSpec", "ShardSpec", "DistributedProjector", "distribute",
    "forward_project", "back_project", "fbp",
]

# fbp has no import cycle with kernels and must be bound eagerly: once the
# `repro.core.fbp` submodule is imported, the module object would shadow a
# lazy attribute of the same name.
from repro.core.fbp import fbp  # noqa: E402

_LAZY = {"Projector": ("repro.core.projector", "Projector"),
         "DistributedProjector": ("repro.core.distributed",
                                  "DistributedProjector"),
         "distribute": ("repro.core.distributed", "distribute"),
         "forward_project": ("repro.kernels.ops", "forward_project"),
         "back_project": ("repro.kernels.ops", "back_project")}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")

"""Filtered backprojection (parallel + fan beam) and FDK (cone beam).

The backprojection used here is the *textbook interpolation backprojector*
(sample the filtered projection at each voxel's detector coordinate), which
gives quantitatively correct values in 1/mm.  It is implemented as its own
vectorized jnp routine rather than reusing the adjoint A^T: the adjoint of
the SF/Joseph forward model carries path-length weights that are correct for
gradients but not for the FBP inversion formula.

For non-equispaced angles the per-view quadrature weight is half the angular
distance between its neighbours (trapezoid rule), matching the paper's
"non-equispaced projection angles" support.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.filters import filter_sinogram
from repro.core.geometry import CTGeometry

_EPS = 1e-9


def _angle_weights(angles: np.ndarray, full_range: float) -> np.ndarray:
    """Trapezoid quadrature weights d_phi for (possibly) non-equispaced views."""
    n = len(angles)
    if n == 1:
        return np.asarray([full_range], dtype=np.float32)
    order = np.argsort(angles)
    srt = np.asarray(angles)[order]
    gaps = np.diff(srt)
    w = np.empty(n)
    w[0] = gaps[0] / 2 + (full_range - (srt[-1] - srt[0])) / 2
    w[-1] = gaps[-1] / 2 + (full_range - (srt[-1] - srt[0])) / 2
    w[1:-1] = (gaps[:-1] + gaps[1:]) / 2
    out = np.empty(n)
    out[order] = w
    return out.astype(np.float32)


def _lerp_matrix(src_coords: np.ndarray, dst_coords: np.ndarray) -> np.ndarray:
    """(n_src, n_dst) dense linear-interpolation matrix (zero outside range)."""
    n_src = len(src_coords)
    d = src_coords[1] - src_coords[0] if n_src > 1 else 1.0
    pos = (dst_coords - src_coords[0]) / d
    j = np.floor(pos).astype(int)
    w = pos - j
    M = np.zeros((n_src, len(dst_coords)), dtype=np.float32)
    for k, (jj, ww) in enumerate(zip(j, w)):
        if 0 <= jj < n_src:
            M[jj, k] += 1 - ww
        if 0 <= jj + 1 < n_src:
            M[jj + 1, k] += ww
    return M


def fbp_parallel(sino, geom: CTGeometry, filter_name: str = "ramp"):
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    q = filter_sinogram(sino, geom.pixel_width, filter_name)     # (na, nv, nu)
    X = jnp.asarray(np.repeat(v.x_coords(), ny))                 # (nxy,)
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    u0, du = float(geom.u_coords()[0]), geom.pixel_width
    Lz = jnp.asarray(_lerp_matrix(geom.v_coords(), v.z_coords()))  # (nv, nz)
    wts = jnp.asarray(_angle_weights(geom.angles_array(), np.pi))
    angs = jnp.asarray(geom.angles_array())

    def one(acc, inp):
        ang, w, qa = inp                                         # qa (nv, nu)
        c, s = jnp.cos(ang), jnp.sin(ang)
        ui = (Y * c - X * s - u0) / du                           # (nxy,)
        j = jnp.floor(ui).astype(jnp.int32)
        t = ui - j
        ok0 = (j >= 0) & (j < nu)
        ok1 = (j + 1 >= 0) & (j + 1 < nu)
        g0 = jnp.take(qa, jnp.clip(j, 0, nu - 1), axis=1)        # (nv, nxy)
        g1 = jnp.take(qa, jnp.clip(j + 1, 0, nu - 1), axis=1)
        S = g0 * jnp.where(ok0, 1 - t, 0.0) + g1 * jnp.where(ok1, t, 0.0)
        return acc + w * jnp.einsum("vq,vz->qz", S, Lz).reshape(nx, ny, nz), 0

    acc0 = jnp.zeros(v.shape, sino.dtype)
    acc, _ = jax.lax.scan(one, acc0, (angs, wts, q))
    return acc


def _fan_gamma(geom: CTGeometry) -> np.ndarray:
    """Fan angle of each detector column (rad)."""
    us = geom.u_coords()
    if geom.detector_type == "curved":
        return us / geom.sdd
    return np.arctan2(us, geom.sdd)


def parker_weights(geom: CTGeometry) -> np.ndarray:
    """Parker (1982) short-scan weights, shape (n_angles, n_cols).

    Smoothly splits the weight of each conjugate ray pair so a
    ``pi + 2*delta`` scan (delta = half fan angle) integrates like a full
    scan.  Views are referenced to the smallest angle; ranges beyond the
    exact short-scan window are clamped to [0, 1]."""
    gamma = _fan_gamma(geom).astype(np.float64)
    delta = float(np.abs(gamma).max())
    ang = np.asarray(geom.angles_array(), np.float64)
    beta = (ang - ang.min())[:, None]                # (na, 1)
    G = gamma[None, :]                               # (1, nu)
    eps = 1e-6
    w = np.ones_like(beta * G)
    # Conjugate of (beta, gamma) is (beta + pi - 2*gamma, -gamma); the ramp
    # arguments below are complementary for such a pair, so w + w_conj = 1.
    r1 = beta < 2.0 * (delta + G)                    # ramp-up region
    a1 = beta / np.maximum(2.0 * (delta + G), eps)
    w = np.where(r1, np.sin(np.pi / 2.0 * np.clip(a1, 0.0, 1.0)) ** 2, w)
    r3 = beta > np.pi + 2.0 * G                      # ramp-down region
    a3 = (np.pi + 2.0 * delta - beta) / np.maximum(2.0 * (delta - G), eps)
    w = np.where(r3, np.sin(np.pi / 2.0 * np.clip(a3, 0.0, 1.0)) ** 2, w)
    return np.clip(w, 0.0, 1.0).astype(np.float32)


def fbp_fan(sino, geom: CTGeometry, filter_name: str = "ramp",
            short_scan: Optional[bool] = None):
    """Fan-beam FBP (flat = equispaced, curved = equiangular columns).

    Weighting chain (Kak & Slaney ch. 3): cosine pre-weight ``cos(gamma)``,
    ramp filter (with the ``(gamma/sin gamma)^2`` kernel correction for
    curved detectors), then distance-weighted backprojection —
    ``sod^2/ell^2`` at flat-detector scale, ``sod*sdd/L^2`` equiangular.
    ``short_scan=None`` auto-detects: an angular span under ~2*pi enables
    Parker weights (and drops the full-scan double-coverage 1/2)."""
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    sod, sdd = geom.sod, geom.sdd
    curved = geom.detector_type == "curved"
    gamma = _fan_gamma(geom)
    cw = jnp.asarray(np.cos(gamma).astype(np.float32))       # cosine pre-weight

    ang = np.asarray(geom.angles_array(), np.float64)
    n = len(ang)
    span = float(ang.max() - ang.min()) * (n / max(n - 1, 1))
    if short_scan is None:
        short_scan = span < 2.0 * np.pi * 0.99
    if short_scan:
        pw = jnp.asarray(parker_weights(geom))               # (na, nu)
        pre = sino * cw[None, None, :] * pw[:, None, :]
        wts = jnp.asarray(_angle_weights(geom.angles_array(), span))
    else:
        pre = sino * cw[None, None, :]
        wts = jnp.asarray(_angle_weights(geom.angles_array(), 2 * np.pi)) / 2.0

    q = filter_sinogram(pre, geom.pixel_width, filter_name,
                        equiangular_sdd=sdd if curved else 0.0)
    if not curved:
        # The ramp acts at detector scale; isocenter frequencies are higher
        # by the magnification sdd/sod (same rescale as FDK).
        q = q * (sdd / sod)

    X = jnp.asarray(np.repeat(v.x_coords(), ny))             # (nxy,)
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    u0, du = float(geom.u_coords()[0]), geom.pixel_width
    Lz = jnp.asarray(_lerp_matrix(geom.v_coords(), v.z_coords()))  # (nv, nz)
    angs = jnp.asarray(geom.angles_array())

    def one(acc, inp):
        ang_, w, qa = inp                                    # qa (nv, nu)
        c, s = jnp.cos(ang_), jnp.sin(ang_)
        ell = jnp.maximum(sod - (X * c + Y * s), _EPS)       # (nxy,)
        t = Y * c - X * s
        if curved:
            ustar = sdd * jnp.arctan2(t, ell)
            wdist = sod * sdd / (ell * ell + t * t)
        else:
            ustar = sdd * t / ell
            wdist = sod ** 2 / (ell * ell)
        ui = (ustar - u0) / du
        j = jnp.floor(ui).astype(jnp.int32)
        frac = ui - j
        ok0 = (j >= 0) & (j < nu)
        ok1 = (j + 1 >= 0) & (j + 1 < nu)
        g0 = jnp.take(qa, jnp.clip(j, 0, nu - 1), axis=1)    # (nv, nxy)
        g1 = jnp.take(qa, jnp.clip(j + 1, 0, nu - 1), axis=1)
        S = g0 * jnp.where(ok0, 1 - frac, 0.0) + g1 * jnp.where(ok1, frac, 0.0)
        S = S * wdist[None, :]
        return acc + w * jnp.einsum("vq,vz->qz", S, Lz).reshape(nx, ny, nz), 0

    acc0 = jnp.zeros(v.shape, sino.dtype)
    acc, _ = jax.lax.scan(one, acc0, (angs, wts, q))
    return acc


def fbp_cone(sino, geom: CTGeometry, filter_name: str = "ramp"):
    """FDK reconstruction (flat detector)."""
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    sod, sdd = geom.sod, geom.sdd
    us = jnp.asarray(geom.u_coords())
    vs = jnp.asarray(geom.v_coords())
    # cosine pre-weight
    cw = sdd / jnp.sqrt(sdd ** 2 + us[None, :] ** 2 + vs[:, None] ** 2)
    q = filter_sinogram(sino * cw[None], geom.pixel_width, filter_name)
    # The ramp filter acts at detector scale; frequencies at the isocenter are
    # higher by the magnification sdd/sod, so rescale the filtered data.
    q = q * (sdd / sod)
    X = jnp.asarray(np.repeat(v.x_coords(), ny))
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    Z = jnp.asarray(v.z_coords())
    u0, du = float(geom.u_coords()[0]), geom.pixel_width
    v0, dv = float(geom.v_coords()[0]), geom.pixel_height
    rng = 2 * np.pi
    wts = jnp.asarray(_angle_weights(geom.angles_array(), rng)) / 2.0
    angs = jnp.asarray(geom.angles_array())

    def one(acc, inp):
        ang, w, qa = inp
        c, s = jnp.cos(ang), jnp.sin(ang)
        ell = sod - (X * c + Y * s)                              # (nxy,)
        ell = jnp.maximum(ell, _EPS)
        ustar = sdd * (Y * c - X * s) / ell
        ui = (ustar - u0) / du
        j = jnp.floor(ui).astype(jnp.int32)
        t = ui - j
        ok0 = (j >= 0) & (j < nu)
        ok1 = (j + 1 >= 0) & (j + 1 < nu)
        g0 = jnp.take(qa, jnp.clip(j, 0, nu - 1), axis=1)
        g1 = jnp.take(qa, jnp.clip(j + 1, 0, nu - 1), axis=1)
        S = g0 * jnp.where(ok0, 1 - t, 0.0) + g1 * jnp.where(ok1, t, 0.0)
        S = S.T                                                  # (nxy, nv)
        vi = (sdd * Z[None, :] / ell[:, None] - v0) / dv         # (nxy, nz)
        jv = jnp.floor(vi).astype(jnp.int32)
        tv = vi - jv
        okv0 = (jv >= 0) & (jv < nv)
        okv1 = (jv + 1 >= 0) & (jv + 1 < nv)
        h0 = jnp.take_along_axis(S, jnp.clip(jv, 0, nv - 1), axis=1)
        h1 = jnp.take_along_axis(S, jnp.clip(jv + 1, 0, nv - 1), axis=1)
        val = h0 * jnp.where(okv0, 1 - tv, 0.0) + h1 * jnp.where(okv1, tv, 0.0)
        val = val * (sod ** 2 / ell[:, None] ** 2)
        return acc + w * val.reshape(nx, ny, nz), 0

    acc0 = jnp.zeros(v.shape, sino.dtype)
    acc, _ = jax.lax.scan(one, acc0, (angs, wts, q))
    return acc


def fbp(sino, geom: CTGeometry, model: str = "sf", backend: str = "auto",
        filter_name: str = "ramp", config=None,
        short_scan: Optional[bool] = None):
    """Analytic reconstruction.

    ``config`` (a :class:`repro.kernels.tune.KernelConfig`) is accepted for
    API uniformity with the projector ops and reserved for a kernelized
    backprojector; the current interpolation backprojectors are pure jnp
    and take no tile sizes.  ``short_scan`` applies only to fan beams
    (Parker weighting; ``None`` auto-detects from the angular span).
    """
    if config is not None:
        from repro.kernels.tune import KernelConfig
        if not isinstance(config, KernelConfig):
            raise TypeError(f"config must be a KernelConfig, got {config!r}")
    if geom.geom_type == "parallel":
        return fbp_parallel(sino, geom, filter_name)
    if geom.geom_type == "fan":
        return fbp_fan(sino, geom, filter_name, short_scan=short_scan)
    if geom.geom_type == "cone":
        if geom.detector_type != "flat":
            raise NotImplementedError("FDK implemented for flat detectors")
        return fbp_cone(sino, geom, filter_name)
    raise NotImplementedError("FBP needs parallel, fan, or cone geometry; "
                              "use iterative recon (repro.recon) for modular")

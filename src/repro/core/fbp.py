"""Filtered backprojection (parallel beam) and FDK (cone beam).

The backprojection used here is the *textbook interpolation backprojector*
(sample the filtered projection at each voxel's detector coordinate), which
gives quantitatively correct values in 1/mm.  It is implemented as its own
vectorized jnp routine rather than reusing the adjoint A^T: the adjoint of
the SF/Joseph forward model carries path-length weights that are correct for
gradients but not for the FBP inversion formula.

For non-equispaced angles the per-view quadrature weight is half the angular
distance between its neighbours (trapezoid rule), matching the paper's
"non-equispaced projection angles" support.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.filters import filter_sinogram
from repro.core.geometry import CTGeometry

_EPS = 1e-9


def _angle_weights(angles: np.ndarray, full_range: float) -> np.ndarray:
    """Trapezoid quadrature weights d_phi for (possibly) non-equispaced views."""
    n = len(angles)
    if n == 1:
        return np.asarray([full_range], dtype=np.float32)
    order = np.argsort(angles)
    srt = np.asarray(angles)[order]
    gaps = np.diff(srt)
    w = np.empty(n)
    w[0] = gaps[0] / 2 + (full_range - (srt[-1] - srt[0])) / 2
    w[-1] = gaps[-1] / 2 + (full_range - (srt[-1] - srt[0])) / 2
    w[1:-1] = (gaps[:-1] + gaps[1:]) / 2
    out = np.empty(n)
    out[order] = w
    return out.astype(np.float32)


def _lerp_matrix(src_coords: np.ndarray, dst_coords: np.ndarray) -> np.ndarray:
    """(n_src, n_dst) dense linear-interpolation matrix (zero outside range)."""
    n_src = len(src_coords)
    d = src_coords[1] - src_coords[0] if n_src > 1 else 1.0
    pos = (dst_coords - src_coords[0]) / d
    j = np.floor(pos).astype(int)
    w = pos - j
    M = np.zeros((n_src, len(dst_coords)), dtype=np.float32)
    for k, (jj, ww) in enumerate(zip(j, w)):
        if 0 <= jj < n_src:
            M[jj, k] += 1 - ww
        if 0 <= jj + 1 < n_src:
            M[jj + 1, k] += ww
    return M


def fbp_parallel(sino, geom: CTGeometry, filter_name: str = "ramp"):
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    q = filter_sinogram(sino, geom.pixel_width, filter_name)     # (na, nv, nu)
    X = jnp.asarray(np.repeat(v.x_coords(), ny))                 # (nxy,)
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    u0, du = float(geom.u_coords()[0]), geom.pixel_width
    Lz = jnp.asarray(_lerp_matrix(geom.v_coords(), v.z_coords()))  # (nv, nz)
    wts = jnp.asarray(_angle_weights(geom.angles_array(), np.pi))
    angs = jnp.asarray(geom.angles_array())

    def one(acc, inp):
        ang, w, qa = inp                                         # qa (nv, nu)
        c, s = jnp.cos(ang), jnp.sin(ang)
        ui = (Y * c - X * s - u0) / du                           # (nxy,)
        j = jnp.floor(ui).astype(jnp.int32)
        t = ui - j
        ok0 = (j >= 0) & (j < nu)
        ok1 = (j + 1 >= 0) & (j + 1 < nu)
        g0 = jnp.take(qa, jnp.clip(j, 0, nu - 1), axis=1)        # (nv, nxy)
        g1 = jnp.take(qa, jnp.clip(j + 1, 0, nu - 1), axis=1)
        S = g0 * jnp.where(ok0, 1 - t, 0.0) + g1 * jnp.where(ok1, t, 0.0)
        return acc + w * jnp.einsum("vq,vz->qz", S, Lz).reshape(nx, ny, nz), 0

    acc0 = jnp.zeros(v.shape, sino.dtype)
    acc, _ = jax.lax.scan(one, acc0, (angs, wts, q))
    return acc


def fbp_cone(sino, geom: CTGeometry, filter_name: str = "ramp"):
    """FDK reconstruction (flat detector)."""
    v = geom.vol
    nx, ny, nz = v.shape
    nu, nv = geom.n_cols, geom.n_rows
    sod, sdd = geom.sod, geom.sdd
    us = jnp.asarray(geom.u_coords())
    vs = jnp.asarray(geom.v_coords())
    # cosine pre-weight
    cw = sdd / jnp.sqrt(sdd ** 2 + us[None, :] ** 2 + vs[:, None] ** 2)
    q = filter_sinogram(sino * cw[None], geom.pixel_width, filter_name)
    # The ramp filter acts at detector scale; frequencies at the isocenter are
    # higher by the magnification sdd/sod, so rescale the filtered data.
    q = q * (sdd / sod)
    X = jnp.asarray(np.repeat(v.x_coords(), ny))
    Y = jnp.asarray(np.tile(v.y_coords(), nx))
    Z = jnp.asarray(v.z_coords())
    u0, du = float(geom.u_coords()[0]), geom.pixel_width
    v0, dv = float(geom.v_coords()[0]), geom.pixel_height
    rng = 2 * np.pi
    wts = jnp.asarray(_angle_weights(geom.angles_array(), rng)) / 2.0
    angs = jnp.asarray(geom.angles_array())

    def one(acc, inp):
        ang, w, qa = inp
        c, s = jnp.cos(ang), jnp.sin(ang)
        ell = sod - (X * c + Y * s)                              # (nxy,)
        ell = jnp.maximum(ell, _EPS)
        ustar = sdd * (Y * c - X * s) / ell
        ui = (ustar - u0) / du
        j = jnp.floor(ui).astype(jnp.int32)
        t = ui - j
        ok0 = (j >= 0) & (j < nu)
        ok1 = (j + 1 >= 0) & (j + 1 < nu)
        g0 = jnp.take(qa, jnp.clip(j, 0, nu - 1), axis=1)
        g1 = jnp.take(qa, jnp.clip(j + 1, 0, nu - 1), axis=1)
        S = g0 * jnp.where(ok0, 1 - t, 0.0) + g1 * jnp.where(ok1, t, 0.0)
        S = S.T                                                  # (nxy, nv)
        vi = (sdd * Z[None, :] / ell[:, None] - v0) / dv         # (nxy, nz)
        jv = jnp.floor(vi).astype(jnp.int32)
        tv = vi - jv
        okv0 = (jv >= 0) & (jv < nv)
        okv1 = (jv + 1 >= 0) & (jv + 1 < nv)
        h0 = jnp.take_along_axis(S, jnp.clip(jv, 0, nv - 1), axis=1)
        h1 = jnp.take_along_axis(S, jnp.clip(jv + 1, 0, nv - 1), axis=1)
        val = h0 * jnp.where(okv0, 1 - tv, 0.0) + h1 * jnp.where(okv1, tv, 0.0)
        val = val * (sod ** 2 / ell[:, None] ** 2)
        return acc + w * val.reshape(nx, ny, nz), 0

    acc0 = jnp.zeros(v.shape, sino.dtype)
    acc, _ = jax.lax.scan(one, acc0, (angs, wts, q))
    return acc


def fbp(sino, geom: CTGeometry, model: str = "sf", backend: str = "auto",
        filter_name: str = "ramp", config=None):
    """Analytic reconstruction.

    ``config`` (a :class:`repro.kernels.tune.KernelConfig`) is accepted for
    API uniformity with the projector ops and reserved for a kernelized
    backprojector; the current interpolation backprojectors are pure jnp
    and take no tile sizes.
    """
    if config is not None:
        from repro.kernels.tune import KernelConfig
        if not isinstance(config, KernelConfig):
            raise TypeError(f"config must be a KernelConfig, got {config!r}")
    if geom.geom_type == "parallel":
        return fbp_parallel(sino, geom, filter_name)
    if geom.geom_type == "cone":
        if geom.detector_type != "flat":
            raise NotImplementedError("FDK implemented for flat detectors")
        return fbp_cone(sino, geom, filter_name)
    raise NotImplementedError("FBP needs parallel or cone geometry; use "
                              "iterative recon (repro.recon) for modular")

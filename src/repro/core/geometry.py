"""CT scanner geometry and reconstruction-volume specifications.

Conventions (all quantities in mm; reconstructed values in 1/mm — the paper's
"quantitatively accurate" requirement):

Volume
    ``f[ix, iy, iz]`` with shape ``(nx, ny, nz)``.  World coordinates::

        x(ix) = (ix - (nx-1)/2) * dx + offset_x          (same for y, z)

    ``z`` is the rotation axis.  ``z`` is deliberately the *last* axis so the
    TPU kernels can put it on the 128-lane dimension (axial geometries are
    embarrassingly vectorizable over z).

Projections (sinogram)
    ``p[ia, iv, iu]`` with shape ``(n_angles, n_rows, n_cols)``; ``v`` indexes
    detector rows (parallel to z), ``u`` detector columns::

        u(iu) = (iu - (nu-1)/2) * du + center_col_mm
        v(iv) = (iv - (nv-1)/2) * dv + center_row_mm

Geometry types (the paper's geometry classes):
    * ``parallel``  — rays along (cos phi, sin phi, 0); detector u-axis is
      (-sin phi, cos phi, 0), v-axis is +z.
    * ``fan``       — 2D divergent beam: point source at radius ``sod`` in the
      transaxial plane, detector at distance ``sdd`` from the source.  Each
      detector row is an independent in-plane fan of the matching z-slab
      (the axial footprint is the parallel-beam rectangle overlap — no axial
      magnification).  ``detector_type="flat"`` means equispaced columns in
      mm on a flat detector; ``"curved"`` means an equiangular arc centered
      on the source, with ``u`` the arc length (mm), i.e. the fan angle is
      ``gamma = u / sdd``.
    * ``cone``      — point source at radius ``sod`` from the rotation axis,
      flat or curved detector at distance ``sdd`` from the source.
      Source position: ``s(phi) = (sod cos phi, sod sin phi, 0)``;
      detector center: ``s - sdd*(cos phi, sin phi, 0)`` (+ shifts).
    * ``modular``   — arbitrary per-view source position / detector center /
      detector (u, v) axes.

The dataclasses are frozen and contain only Python scalars / tuples /
numpy arrays so a geometry instance is *static metadata*: it is safe (and
intended) to close over it inside ``jax.jit``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "VolumeGeometry",
    "CTGeometry",
    "parallel_beam",
    "fan_beam",
    "cone_beam",
    "modular_beam",
    "helical_beam",
    "from_config",
]


def _as_f32(a) -> np.ndarray:
    return np.asarray(a, dtype=np.float32)


def _canon_value(v):
    """Canonicalize one geometry field for the stable content key.

    Floats round through float32 (what every kernel consumes) so python
    floats and numpy scalars of the same value serialize identically; arrays
    are replaced by a content digest of their canonical float32 bytes."""
    if isinstance(v, np.ndarray):
        a = np.ascontiguousarray(v, dtype=np.float32)
        return ["ndarray", list(a.shape),
                hashlib.sha256(a.tobytes()).hexdigest()]
    if isinstance(v, (bool, str)) or v is None:
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(np.float32(v))
    if isinstance(v, (tuple, list)):
        return [_canon_value(x) for x in v]
    if isinstance(v, dict):
        return {k: _canon_value(x) for k, x in sorted(v.items())}
    return str(v)


@dataclasses.dataclass(frozen=True)
class VolumeGeometry:
    """Reconstruction volume: ``(nx, ny, nz)`` voxels of size ``(dx, dy, dz)`` mm."""

    nx: int
    ny: int
    nz: int
    dx: float = 1.0
    dy: float = 1.0
    dz: float = 1.0
    offset_x: float = 0.0
    offset_y: float = 0.0
    offset_z: float = 0.0

    def __post_init__(self):
        if self.nx <= 0 or self.ny <= 0 or self.nz <= 0:
            raise ValueError(f"volume dims must be positive, got {(self.nx, self.ny, self.nz)}")
        if self.dx <= 0 or self.dy <= 0 or self.dz <= 0:
            raise ValueError("voxel sizes must be positive")
        if not math.isclose(self.dx, self.dy, rel_tol=1e-6):
            # The SF transaxial footprint assumes square in-plane voxels
            # (same restriction as LEAP).
            raise ValueError("in-plane voxels must be square (dx == dy)")

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.nx, self.ny, self.nz)

    def x_coords(self) -> np.ndarray:
        return _as_f32((np.arange(self.nx) - (self.nx - 1) / 2.0) * self.dx + self.offset_x)

    def y_coords(self) -> np.ndarray:
        return _as_f32((np.arange(self.ny) - (self.ny - 1) / 2.0) * self.dy + self.offset_y)

    def z_coords(self) -> np.ndarray:
        return _as_f32((np.arange(self.nz) - (self.nz - 1) / 2.0) * self.dz + self.offset_z)

    @property
    def radius(self) -> float:
        """Circumscribing transaxial radius of the volume (mm)."""
        rx = self.nx * self.dx / 2.0 + abs(self.offset_x)
        ry = self.ny * self.dy / 2.0 + abs(self.offset_y)
        return math.hypot(rx, ry)

    def scale(self, s: float) -> "VolumeGeometry":
        return dataclasses.replace(
            self, dx=self.dx * s, dy=self.dy * s, dz=self.dz * s,
            offset_x=self.offset_x * s, offset_y=self.offset_y * s,
            offset_z=self.offset_z * s)


@dataclasses.dataclass(frozen=True)
class CTGeometry:
    """Full scanner description: projections layout + beam geometry + volume."""

    geom_type: str                      # "parallel" | "fan" | "cone" | "modular"
    vol: VolumeGeometry
    n_angles: int
    n_rows: int                         # detector rows (v / axial)
    n_cols: int                         # detector columns (u / transaxial)
    pixel_height: float = 1.0           # dv, mm
    pixel_width: float = 1.0            # du, mm
    # Either an angular range (equispaced) or an explicit tuple of angles (rad).
    angles: Tuple[float, ...] = ()
    sod: float = 0.0                    # source-to-object distance (cone)
    sdd: float = 0.0                    # source-to-detector distance (cone)
    center_row: float = 0.0             # vertical detector shift, mm
    center_col: float = 0.0             # horizontal detector shift, mm
    detector_type: str = "flat"         # "flat" | "curved"  (cone only)
    # Modular geometry: per-view 3-vectors, shape (n_angles, 3).
    source_pos: Optional[np.ndarray] = None
    det_center: Optional[np.ndarray] = None
    det_u: Optional[np.ndarray] = None  # unit vector along columns
    det_v: Optional[np.ndarray] = None  # unit vector along rows

    def __post_init__(self):
        if self.geom_type not in ("parallel", "fan", "cone", "modular"):
            raise ValueError(f"unknown geometry type {self.geom_type!r}")
        if self.n_angles <= 0 or self.n_rows <= 0 or self.n_cols <= 0:
            raise ValueError("projection dims must be positive")
        if self.pixel_width <= 0 or self.pixel_height <= 0:
            raise ValueError("pixel sizes must be positive")
        if len(self.angles) != self.n_angles and self.geom_type != "modular":
            raise ValueError(
                f"angles has {len(self.angles)} entries, expected n_angles={self.n_angles}")
        if self.geom_type in ("fan", "cone"):
            if not (self.sdd > self.sod > 0):
                raise ValueError(
                    f"{self.geom_type} beam requires sdd > sod > 0")
            if self.detector_type not in ("flat", "curved"):
                raise ValueError(f"unknown detector type {self.detector_type!r}")
            if self.sod <= self.vol.radius:
                raise ValueError(
                    f"source (sod={self.sod}) inside volume radius {self.vol.radius:.2f}")
        if self.geom_type == "fan" and self.detector_type == "curved":
            # arc length must stay inside the half circle around the source
            umax = (self.n_cols - 1) / 2.0 * self.pixel_width + abs(self.center_col)
            if umax / self.sdd >= math.pi / 2:
                raise ValueError(
                    "curved fan detector spans a fan angle >= pi/2; widen sdd "
                    "or shrink the detector")
        if self.geom_type == "modular":
            for name in ("source_pos", "det_center", "det_u", "det_v"):
                v = getattr(self, name)
                if v is None or np.asarray(v).shape != (self.n_angles, 3):
                    raise ValueError(f"modular geometry needs {name} with shape (n_angles, 3)")

    # ------------------------------------------------------------------ #
    @property
    def sino_shape(self) -> Tuple[int, int, int]:
        return (self.n_angles, self.n_rows, self.n_cols)

    def angles_array(self) -> np.ndarray:
        return _as_f32(self.angles)

    def u_coords(self) -> np.ndarray:
        return _as_f32((np.arange(self.n_cols) - (self.n_cols - 1) / 2.0)
                       * self.pixel_width + self.center_col)

    def v_coords(self) -> np.ndarray:
        return _as_f32((np.arange(self.n_rows) - (self.n_rows - 1) / 2.0)
                       * self.pixel_height + self.center_row)

    @property
    def magnification(self) -> float:
        return self.sdd / self.sod if self.geom_type in ("fan", "cone") else 1.0

    def max_footprint_cols(self) -> int:
        """Static bound on how many detector columns one voxel can cover (SF)."""
        mag = 1.0
        if self.geom_type in ("fan", "cone"):
            # A curved (equiangular) fan footprint in arc length is never wider
            # than the flat-detector one at the same sdd, so the flat bound
            # covers both detector types.
            mag = self.sdd / max(self.sod - self.vol.radius, 1e-3)
        width = math.sqrt(2.0) * self.vol.dx * mag
        return int(math.ceil(width / self.pixel_width)) + 2

    def max_footprint_rows(self) -> int:
        """Static bound on detector rows covered by one voxel (SF, axial).
        Fan beams are in-plane: rows see the parallel-beam (unmagnified)
        rectangle overlap."""
        mag = 1.0
        if self.geom_type == "cone":
            mag = self.sdd / max(self.sod - self.vol.radius, 1e-3)
        return int(math.ceil(self.vol.dz * mag / self.pixel_height)) + 2

    def with_angles(self, angles) -> "CTGeometry":
        angles = tuple(float(a) for a in np.asarray(angles).ravel())
        return dataclasses.replace(self, angles=angles, n_angles=len(angles))

    def subset(self, idx) -> "CTGeometry":
        """Geometry restricted to a subset of views (few-view / limited-angle)."""
        idx = np.asarray(idx)
        kw = {}
        if self.geom_type == "modular":
            for name in ("source_pos", "det_center", "det_u", "det_v"):
                kw[name] = np.asarray(getattr(self, name))[idx]
            return dataclasses.replace(self, n_angles=len(idx), angles=(0.0,) * 0, **kw)
        ang = tuple(np.asarray(self.angles)[idx].tolist())
        return dataclasses.replace(self, angles=ang, n_angles=len(idx))

    # Hashable / usable as a static jit argument.
    def key(self) -> str:
        """Canonical content serialization — stable across construction paths.

        Two geometries describing the same scanner must produce the *same*
        string no matter how they were built (constructor call, ``from_config``
        round-trip, numpy vs python scalars): this key is the op-cache key and
        the serving admission-bucket key, so an unstable serialization would
        silently duplicate compiled kernels and split server batches.

        Stability rules:
          * every scalar float is canonicalized through float32 (the dtype
            all kernels consume) before serialization, so ``sod=200.0`` and
            ``sod=np.float32(200)`` collide — previously numpy scalars fell
            into ``json.dumps(default=str)`` and produced a *different* key
            than an equal python float;
          * per-view modular frame arrays are hashed by *content* (sha256 of
            their canonical float32 bytes), never by repr — identical frames
            always share a key, and the key stays short for 1000-view scans.
        """
        cached = getattr(self, "_key_cache", None)
        if cached is not None:
            return cached
        d = dataclasses.asdict(self)
        canon = {k: _canon_value(v) for k, v in sorted(d.items())}
        out = json.dumps(canon, sort_keys=True)
        object.__setattr__(self, "_key_cache", out)
        return out

    def canonical_hash(self) -> str:
        """Short content digest of :meth:`key` — equal geometries (up to the
        float32 precision the kernels run at) share this hash.  This is the
        serving layer's admission-bucket key and part of
        ``ProjectorSpec.cache_key()``."""
        cached = getattr(self, "_hash_cache", None)
        if cached is not None:
            return cached
        h = hashlib.sha256(self.key().encode()).hexdigest()[:16]
        object.__setattr__(self, "_hash_cache", h)
        return h

    def to_config(self) -> dict:
        """Plain JSON-serializable dict accepted by :func:`from_config`.

        Round-trip contract (the serving layer relies on it):
        ``from_config(g.to_config()).canonical_hash() == g.canonical_hash()``.
        """
        vol = dataclasses.asdict(self.vol)
        if self.geom_type == "modular":
            return {
                "geom_type": "modular", "volume": vol,
                "n_rows": self.n_rows, "n_cols": self.n_cols,
                "pixel_width": self.pixel_width,
                "pixel_height": self.pixel_height,
                "source_pos": np.asarray(self.source_pos).tolist(),
                "det_center": np.asarray(self.det_center).tolist(),
                "det_u": np.asarray(self.det_u).tolist(),
                "det_v": np.asarray(self.det_v).tolist(),
            }
        cfg = {
            "geom_type": self.geom_type, "volume": vol,
            "n_angles": self.n_angles, "n_rows": self.n_rows,
            "n_cols": self.n_cols,
            "pixel_width": self.pixel_width,
            "pixel_height": self.pixel_height,
            "angles": list(self.angles),
            "center_row": self.center_row, "center_col": self.center_col,
        }
        if self.geom_type in ("fan", "cone"):
            cfg.update(sod=self.sod, sdd=self.sdd,
                       detector_type=self.detector_type)
        return cfg


# ---------------------------------------------------------------------- #
# Constructors
# ---------------------------------------------------------------------- #
def _equi_angles(n: int, arange_deg: float, start_deg: float = 0.0) -> Tuple[float, ...]:
    a = start_deg + np.arange(n) * (arange_deg / n)
    return tuple(np.deg2rad(a).tolist())


def parallel_beam(n_angles: int, n_rows: int, n_cols: int, vol: VolumeGeometry,
                  pixel_width: float = 1.0, pixel_height: float = 1.0,
                  angular_range: float = 180.0, angles=None,
                  center_row: float = 0.0, center_col: float = 0.0) -> CTGeometry:
    ang = (tuple(float(x) for x in np.asarray(angles).ravel()) if angles is not None
           else _equi_angles(n_angles, angular_range))
    return CTGeometry("parallel", vol, n_angles, n_rows, n_cols,
                      pixel_height, pixel_width, ang,
                      center_row=center_row, center_col=center_col)


def fan_beam(n_angles: int, n_rows: int, n_cols: int, vol: VolumeGeometry,
             sod: float, sdd: float,
             pixel_width: float = 1.0, pixel_height: float = 1.0,
             angular_range: float = 360.0, angles=None,
             center_row: float = 0.0, center_col: float = 0.0,
             detector_type: str = "flat") -> CTGeometry:
    """Fan-beam scanner: ``detector_type="flat"`` gives equispaced columns,
    ``"curved"`` an equiangular arc (``u`` = arc length, fan angle u/sdd)."""
    ang = (tuple(float(x) for x in np.asarray(angles).ravel()) if angles is not None
           else _equi_angles(n_angles, angular_range))
    return CTGeometry("fan", vol, n_angles, n_rows, n_cols,
                      pixel_height, pixel_width, ang, sod=sod, sdd=sdd,
                      center_row=center_row, center_col=center_col,
                      detector_type=detector_type)


def cone_beam(n_angles: int, n_rows: int, n_cols: int, vol: VolumeGeometry,
              sod: float, sdd: float,
              pixel_width: float = 1.0, pixel_height: float = 1.0,
              angular_range: float = 360.0, angles=None,
              center_row: float = 0.0, center_col: float = 0.0,
              detector_type: str = "flat") -> CTGeometry:
    ang = (tuple(float(x) for x in np.asarray(angles).ravel()) if angles is not None
           else _equi_angles(n_angles, angular_range))
    return CTGeometry("cone", vol, n_angles, n_rows, n_cols,
                      pixel_height, pixel_width, ang, sod=sod, sdd=sdd,
                      center_row=center_row, center_col=center_col,
                      detector_type=detector_type)


def modular_beam(source_pos, det_center, det_u, det_v,
                 n_rows: int, n_cols: int, vol: VolumeGeometry,
                 pixel_width: float = 1.0, pixel_height: float = 1.0) -> CTGeometry:
    source_pos = _as_f32(source_pos)
    n = source_pos.shape[0]
    return CTGeometry("modular", vol, n, n_rows, n_cols,
                      pixel_height, pixel_width, tuple([0.0] * n),
                      source_pos=source_pos, det_center=_as_f32(det_center),
                      det_u=_as_f32(det_u), det_v=_as_f32(det_v))


def helical_beam(n_turns: float, pitch: float, n_angles: int,
                 n_rows: int, n_cols: int, vol: VolumeGeometry,
                 sod: float, sdd: float,
                 pixel_width: float = 1.0, pixel_height: float = 1.0,
                 start_angle: float = 0.0,
                 z_start: Optional[float] = None) -> CTGeometry:
    """Helical (spiral) cone-beam trajectory, expressed as modular frames.

    The source orbits the rotation axis at radius ``sod`` while translating
    along z at ``pitch`` mm per full turn; the detector rides opposite the
    source at distance ``sdd``, rows parallel to the rotation axis (the
    standard diagnostic-CT frame, which the modular Pallas SF pair supports
    on-kernel).  ``n_angles`` views are spread uniformly over
    ``n_turns * 360`` degrees starting at ``start_angle`` (rad).

    ``z_start`` is the source z at the first view; the default starts the
    helix at ``offset_z - span/2`` with ``span = n_turns * pitch``.  Views
    sample the span *endpoint-exclusively*, matching the angular grid (view
    ``i`` sits at fraction ``i/n_angles`` of both the azimuth and the z
    travel), so the last view is one z-step below ``offset_z + span/2`` —
    exactly as the next turn's first view would coincide with it in angle.
    """
    if n_turns <= 0 or pitch < 0:
        raise ValueError(f"need n_turns > 0 and pitch >= 0, "
                         f"got {(n_turns, pitch)}")
    t = np.arange(n_angles) / n_angles                 # [0, 1)
    phi = start_angle + 2.0 * math.pi * n_turns * t
    span = n_turns * pitch
    z0 = (vol.offset_z - span / 2.0) if z_start is None else z_start
    z = z0 + span * t
    c, s = np.cos(phi), np.sin(phi)
    src = np.stack([sod * c, sod * s, z], -1)
    ctr = np.stack([(sod - sdd) * c, (sod - sdd) * s, z], -1)
    du = np.stack([-s, c, np.zeros_like(c)], -1)
    dv = np.stack([np.zeros_like(c), np.zeros_like(c), np.ones_like(c)], -1)
    return modular_beam(src, ctr, du, dv, n_rows, n_cols, vol,
                        pixel_width, pixel_height)


def cone_as_modular(g: CTGeometry) -> CTGeometry:
    """Re-express an axial cone-beam geometry in modular form (for testing the
    modular path against the cone path)."""
    if g.geom_type != "cone" or g.detector_type != "flat":
        raise ValueError(
            f"cone_as_modular needs a flat-detector cone geometry, got "
            f"geom_type={g.geom_type!r} detector_type="
            f"{getattr(g, 'detector_type', None)!r}")
    ang = np.asarray(g.angles)
    c, s = np.cos(ang), np.sin(ang)
    src = np.stack([g.sod * c, g.sod * s, np.zeros_like(c)], -1)
    ctr = np.stack([(g.sod - g.sdd) * c - g.center_col * (-s),
                    (g.sod - g.sdd) * s - g.center_col * c,
                    np.full_like(c, -g.center_row)], -1)
    # det_center is the *physical* location of detector coordinate (u=0,v=0)
    # minus shifts; keep shifts inside u/v coords instead:
    ctr = np.stack([(g.sod - g.sdd) * c, (g.sod - g.sdd) * s, np.zeros_like(c)], -1)
    du = np.stack([-s, c, np.zeros_like(c)], -1)
    dv = np.stack([np.zeros_like(c), np.zeros_like(c), np.ones_like(c)], -1)
    return modular_beam(src, ctr, du, dv, g.n_rows, g.n_cols, g.vol,
                        g.pixel_width, g.pixel_height)


def from_config(cfg: dict) -> CTGeometry:
    """Build a geometry from a plain dict (e.g. parsed from a JSON/YAML file) —
    the paper's 'configuration file' interface."""
    cfg = dict(cfg)
    vol = VolumeGeometry(**cfg.pop("volume"))
    t = cfg.pop("geom_type")
    if t == "parallel":
        return parallel_beam(vol=vol, **cfg)
    if t == "fan":
        return fan_beam(vol=vol, **cfg)
    if t == "cone":
        return cone_beam(vol=vol, **cfg)
    if t == "modular":
        return modular_beam(vol=vol, **cfg)
    if t == "helical":
        # Convenience spelling: the emitted geometry is geom_type="modular"
        # (helical frames are modular frames), but configuration files can
        # carry the compact (n_turns, pitch, sod, sdd) description.
        return helical_beam(vol=vol, **cfg)
    raise ValueError(f"unknown geom_type {t!r}")

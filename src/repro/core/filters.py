"""Ramp filters for FBP/FDK, applied along the detector-column axis via FFT.

Frequencies are physical (cycles/mm, spacing = pixel_width) so reconstructed
values come out in 1/mm — the paper's quantitative-units requirement.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

_WINDOWS = ("ramp", "shepp-logan", "hann", "cosine")


def ramp_kernel_freq(n_pad: int, du: float, filter_name: str = "ramp",
                     equiangular_sdd: float = 0.0) -> np.ndarray:
    """|nu| (cycles/mm) times an apodization window, for rfft of length n_pad.

    Uses the band-limited discrete ramp (Kak & Slaney eq. 61): the DC term of
    the spatial kernel is 1/(4 du^2), which avoids the DC bias of a naive
    |nu| sampling.

    ``equiangular_sdd > 0`` applies the equiangular fan-beam correction
    (Kak & Slaney eq. 92): the spatial kernel taps are multiplied by
    ``(gamma / sin gamma)^2`` with ``gamma = n * du / sdd`` — the ramp for
    data sampled on an arc of radius sdd rather than a line."""
    # spatial-domain band-limited ramp kernel h[n]
    n = np.arange(-(n_pad // 2), n_pad - n_pad // 2)
    h = np.zeros(n_pad)
    h[n == 0] = 1.0 / (4.0 * du * du)
    odd = n % 2 == 1
    h[odd] = -1.0 / (np.pi * np.pi * n[odd] ** 2 * du * du)
    if equiangular_sdd > 0:
        gam = n * du / equiangular_sdd
        sg = np.sin(gam)
        corr = np.ones_like(h)
        nz = np.abs(sg) > 1e-12
        corr[nz] = (gam[nz] / sg[nz]) ** 2
        # Taps in the zero-padded tail can reach |gamma| ~ pi where the
        # correction diverges; they carry ~1/n^2 energy, so cap the factor.
        h = h * np.clip(corr, 1.0, 10.0)
    H = np.abs(np.fft.rfft(np.fft.ifftshift(h)))  # ~|nu|/du, band-limited
    freq = np.fft.rfftfreq(n_pad, d=du)
    nyq = freq[-1] if freq[-1] > 0 else 1.0
    if filter_name == "ramp":
        w = np.ones_like(freq)
    elif filter_name == "shepp-logan":
        w = np.sinc(freq / (2.0 * nyq))
    elif filter_name == "hann":
        w = 0.5 * (1.0 + np.cos(np.pi * freq / nyq))
    elif filter_name == "cosine":
        w = np.cos(0.5 * np.pi * freq / nyq)
    else:
        raise ValueError(f"unknown filter {filter_name!r}; choose from {_WINDOWS}")
    return (H * w).astype(np.float32)


def filter_sinogram(sino, du: float, filter_name: str = "ramp",
                    equiangular_sdd: float = 0.0):
    """Apply the ramp filter along the last axis (detector columns).

    sino: (..., n_cols).  Zero-pads to the next power of two >= 2*n_cols to
    avoid circular-convolution wrap-around.  ``equiangular_sdd``: see
    :func:`ramp_kernel_freq`."""
    nu = sino.shape[-1]
    n_pad = 1 << int(np.ceil(np.log2(max(2 * nu, 8))))
    H = jnp.asarray(ramp_kernel_freq(n_pad, du, filter_name, equiangular_sdd))
    S = jnp.fft.rfft(sino, n=n_pad, axis=-1)
    q = jnp.fft.irfft(S * H, n=n_pad, axis=-1)[..., :nu]
    return q.astype(sino.dtype) * du

"""Fault-tolerance runtime: heartbeats, straggler detection, elastic re-mesh
planning, and a supervised restart wrapper.

On a real multi-host deployment each host runs a ``Heartbeat`` publisher and
the rank-0 ``FleetMonitor`` consumes them (file-, KV-store- or RPC-backed; the
transport here is a pluggable callback so tests can drive it synchronously).
The *decisions* — when to declare a straggler, when to shrink the mesh, what
the replacement mesh looks like, and where training resumes from — are
implemented and unit-tested here; they are transport-independent.

Recovery model (1000+ node posture):
* node loss   -> restart from the latest atomic checkpoint on a re-formed
                 mesh (``plan_remesh``): the data axis shrinks to the largest
                 feasible size, 'model' (ICI-local) stays intact;
* straggler   -> flagged by the z-score policy after ``grace`` steps; the
                 supervisor excludes it at the next restart boundary;
* restart     -> ``Supervisor.run`` wraps the train loop, catches
                 checkpoint-restorable failures and resumes with backoff.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class HostStatus:
    host_id: int
    step: int
    step_time_s: float
    timestamp: float


class FleetMonitor:
    """Consumes per-host heartbeats; decides dead hosts + stragglers."""

    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 straggler_zscore: float = 3.0, grace_steps: int = 10):
        self.n_hosts = n_hosts
        self.timeout_s = timeout_s
        self.z = straggler_zscore
        self.grace = grace_steps
        self.status: Dict[int, HostStatus] = {}

    def heartbeat(self, hs: HostStatus):
        self.status[hs.host_id] = hs

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.time() if now is None else now
        dead = [h for h in range(self.n_hosts) if h not in self.status]
        dead += [h for h, s in self.status.items()
                 if now - s.timestamp > self.timeout_s]
        return sorted(set(dead))

    def stragglers(self) -> List[int]:
        if len(self.status) < max(4, self.n_hosts // 2):
            return []
        ts = np.asarray([s.step_time_s for s in self.status.values()])
        med = np.median(ts)
        mad = np.median(np.abs(ts - med)) + 1e-9
        out = []
        for h, s in self.status.items():
            if s.step > self.grace and (s.step_time_s - med) / (1.4826 * mad) > self.z:
                out.append(h)
        return sorted(out)


def plan_remesh(n_healthy_chips: int, model_axis: int = 16,
                pods: int = 1) -> Optional[Tuple[int, ...]]:
    """Largest (pod, data, model) mesh that fits the healthy chip count.
    'model' is ICI-local and must stay intact; we shrink 'data' (and then
    'pod').  Returns None if no viable mesh remains."""
    for p in range(pods, 0, -1):
        data = n_healthy_chips // (p * model_axis)
        # keep the global batch divisible: use the largest power-of-two data
        while data > 0 and (data & (data - 1)):
            data -= 1
        if data >= 1:
            return (p, data, model_axis) if pods > 1 else (data, model_axis)
    return None


class Supervisor:
    """Checkpoint-restart wrapper around a train loop.

    ``loop_fn(start_step) -> final_step`` must raise on failure and is
    expected to save checkpoints via the AsyncCheckpointer; ``restore_fn()``
    returns the step to resume from (latest checkpoint, or 0)."""

    def __init__(self, loop_fn: Callable[[int], int],
                 restore_fn: Callable[[], int],
                 max_restarts: int = 10, backoff_s: float = 1.0):
        self.loop_fn = loop_fn
        self.restore_fn = restore_fn
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0

    def run(self) -> int:
        while True:
            start = self.restore_fn()
            try:
                return self.loop_fn(start)
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception as e:  # noqa: BLE001 — any step failure is retryable
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError(
                        f"giving up after {self.restarts - 1} restarts") from e
                time.sleep(self.backoff_s * min(2 ** (self.restarts - 1), 60))

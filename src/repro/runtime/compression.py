"""Gradient compression for the (slow, inter-pod) data-parallel axis.

Error-feedback 1-bit sign compression (Seide et al. / Bernstein et al.):
the update transmitted per leaf is  sign(g + e) * mean|g + e|  and the
quantization residual e is carried to the next step.  Cuts pod-to-pod
all-reduce bytes by ~16x (fp32->sign+scale); the residual keeps convergence
(tested in tests/test_runtime.py on a quadratic problem).

Usage: wraps the gradient tree *before* the optimizer; state (residuals)
lives alongside optimizer state and is checkpointed with it.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def init_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, residual) -> Tuple[dict, dict]:
    """Returns (decompressed-equivalent grads, new residual).

    The returned grads are what the receiving side reconstructs
    (sign * scale); in a real deployment only (sign bits, scale) cross the
    pod link — the arithmetic here is identical."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.mean(jnp.abs(x))
        q = jnp.sign(x) * scale
        return q.astype(g.dtype), x - q

    out = jax.tree.map(one, grads, residual)
    q = jax.tree.map(lambda t: t[0], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    e = jax.tree.map(lambda t: t[1], out,
                     is_leaf=lambda t: isinstance(t, tuple))
    return q, e


def compressed_bytes(params) -> int:
    """Bytes per step crossing the DP axis with 1-bit EF (sign bits + scale)."""
    return sum(int(np.ceil(p.size / 8)) + 4 for p in jax.tree.leaves(params))


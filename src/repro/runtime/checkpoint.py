"""Fault-tolerant sharded checkpointing (no orbax/tensorstore in this stack).

Layout:  <dir>/step_<N>/
             manifest.json            tree structure + shapes + dtypes + step
             <leafkey>.npy            one file per pytree leaf (local shard
                                      per host in a real multi-host run)
         <dir>/LATEST                 atomically-updated pointer

Guarantees:
* step-atomic: the step directory is staged under a tmp name and renamed,
  and LATEST is written+fsynced+renamed only after all leaves land — a crash
  mid-save can never corrupt the restore point;
* async: ``save_async`` snapshots to host memory (device_get) synchronously
  and writes on a background thread, so the train loop blocks only for the
  device->host copy;
* restore replays data-pipeline state (seed/step) so the token/phantom
  stream continues exactly where it left off.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro import compat


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in compat.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p.key if hasattr(p, "key") else p.idx
                           if hasattr(p, "idx") else p) for p in path)
        flat[key] = leaf
    return flat


def _tree_structure_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None):
    """Synchronous atomic save."""
    flat = _flatten(jax.tree.map(np.asarray, jax.device_get(tree)))
    _write(ckpt_dir, step, flat, extra or {})


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        flat = _flatten(jax.tree.map(np.asarray, jax.device_get(tree)))
        self._thread = threading.Thread(
            target=self._save_bg, args=(step, flat, extra or {}), daemon=True)
        self._thread.start()

    def _save_bg(self, step, flat, extra):
        _write(self.dir, step, flat, extra)
        _gc(self.dir, self.keep)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _write(ckpt_dir: str, step: int, flat: dict, extra: dict):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"step": step, "extra": extra, "leaves": {}}
    for key, arr in flat.items():
        fn = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {"file": fn, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(latest_tmp, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if re.fullmatch(r"step_\d+", d))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    name = open(p).read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, tree_like: Any,
            step: Optional[int] = None) -> Tuple[Any, dict, int]:
    """Restore into the structure of ``tree_like`` (shapes validated).
    Returns (tree, extra, step)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    flat_like = _flatten(tree_like)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        if key in flat_like and tuple(arr.shape) != tuple(flat_like[key].shape):
            raise ValueError(f"checkpoint leaf {key} shape {arr.shape} != "
                             f"expected {flat_like[key].shape}")
        leaves[key] = arr
    missing = set(flat_like) - set(leaves)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}...")
    # rebuild in tree_like's structure
    paths = compat.tree_flatten_with_path(tree_like)
    keys_in_order = []
    for path, _ in paths[0]:
        keys_in_order.append("/".join(
            str(p.key if hasattr(p, "key") else p.idx if hasattr(p, "idx")
                else p) for p in path))
    rebuilt = jax.tree_util.tree_unflatten(
        paths[1], [leaves[k] for k in keys_in_order])
    return rebuilt, manifest["extra"], manifest["step"]

"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32, MHA) d_ff=8192,
decoder-only over EnCodec tokens: 4 codebooks (delay pattern applied in the
data layer), vocab 2048 per codebook; EnCodec frontend is a stub.
[arXiv:2306.05284]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=4,
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
        vocab_size=2048, mlp="gelu", rope="standard",
        n_codebooks=4,
    )

"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16, MHA) d_ff=1024/expert
vocab=50304, MoE 64 experts top-8.  [arXiv:2409.02060]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=2,
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
        vocab_size=50304, mlp="swiglu", rope="standard", qk_norm=True,
        moe=MoEConfig(n_experts=64, top_k=8, expert_d_ff=1024),
    )

"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE; vision frontend is a stub (input_specs supplies
precomputed patch embeddings).  [arXiv:2409.12191]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=8, seq_shard=True,
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
        vocab_size=152064, mlp="swiglu", rope="mrope",
        mrope_sections=(16, 24, 24), rope_theta=1_000_000.0,
        vision_tokens=1024,
    )

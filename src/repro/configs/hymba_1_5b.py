"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
parallel attention + Mamba heads, SWA with periodic global layers,
ssm_state=16.  [arXiv:2411.13676]"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=4,
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
        d_ff=5504, vocab_size=32001, mlp="swiglu", rope="standard",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
        sliding_window=2048, global_attn_every=16,
    )

"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — squared-ReLU MLP.  [arXiv:2402.16819]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=8, seq_shard=True,
        name="nemotron-4-340b", family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
        vocab_size=256000, mlp="sq_relu", rope="standard",
    )

"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=8, seq_shard=True,
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
        vocab_size=131072, mlp="gelu", rope="standard",
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32768),
    )

"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, GQA, head_dim=128.  [hf:Qwen/Qwen3-8B family]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936, mlp="swiglu", rope="standard",
        rope_theta=1_000_000.0, qk_norm=True,
    )

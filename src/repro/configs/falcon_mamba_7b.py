"""falcon-mamba-7b [ssm]: 64L d_model=4096, attention-free Mamba-1, vocab 65024,
ssm_state=16.  [arXiv:2410.05355]"""
from repro.models.config import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        grad_accum=8,
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=65024, mlp="none", rope="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    )

"""The paper's own workloads: projection geometries from Table 1 and the
limited-angle experiment (512^2 image, 720-view parallel beam)."""
from repro.core.geometry import VolumeGeometry, cone_beam, parallel_beam


def table1_geometries(reduced: bool = False):
    """The four Table-1 cells: (parallel|cone) x (512^3/180 | 1024^3/720).
    ``reduced`` scales to CPU-runnable sizes, keeping aspect ratios."""
    cells = {}
    for n, na in ((512, 180), (1024, 720)):
        nn, nna = ((n // 8, na // 6) if n <= 512 else (n // 16, na // 12)) \
            if reduced else (n, na)
        vol = VolumeGeometry(nn, nn, nn)
        cells[f"parallel_{n}_{na}"] = parallel_beam(
            nna, nn, int(nn * 1.5), vol, angular_range=180.0)
        cells[f"cone_{n}_{na}"] = cone_beam(
            nna, nn, int(nn * 1.5), vol, sod=2.0 * nn, sdd=4.0 * nn,
            pixel_width=2.0, pixel_height=2.0, angular_range=360.0)
    return cells


def limited_angle_geometry(n: int = 512, n_angles: int = 720):
    vol = VolumeGeometry(n, n, 1)
    return parallel_beam(n_angles, 1, int(n * 1.5), vol, angular_range=180.0)

"""Architecture registry: one module per assigned architecture.

``get(name)``     -> full-scale ModelConfig (used by the multi-pod dry-run)
``get_smoke(name)`` -> reduced same-family config (CPU smoke tests)
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

# Re-exported alongside the registry so callers can type against the config
# dataclasses without reaching into repro.models.config.
__all__ = ["ARCHS", "ModelConfig", "MoEConfig", "SSMConfig", "get",
           "get_smoke"]

ARCHS = [
    "falcon_mamba_7b", "tinyllama_1_1b", "qwen3_0_6b", "nemotron_4_340b",
    "starcoder2_3b", "grok_1_314b", "olmoe_1b_7b", "hymba_1_5b",
    "qwen2_vl_72b", "musicgen_large",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    n = name.replace("-", "_").replace(".", "_")
    if n not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return n


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.config()


def get_smoke(name: str) -> ModelConfig:
    """Reduced config of the same family: tiny dims, same structural features
    (GQA ratio, qk-norm, MoE top-k, SSM, M-RoPE, codebooks...)."""
    cfg = get(name)
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = kv * max(1, min(cfg.n_heads // max(cfg.n_kv_heads, 1), 4))
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, n_experts=4,
                                  top_k=min(cfg.moe.top_k, 2),
                                  expert_d_ff=64)
    ssm = None
    if cfg.ssm is not None:
        ssm = dataclasses.replace(cfg.ssm, d_state=8, dt_rank=8)
    return dataclasses.replace(
        cfg,
        n_layers=2, d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128, vocab_size=512,
        moe=moe, ssm=ssm,
        sliding_window=(32 if cfg.sliding_window else None),
        global_attn_every=(2 if cfg.global_attn_every else 0),
        vision_tokens=(8 if cfg.vision_tokens else 0),
        mrope_sections=(2, 3, 3) if cfg.rope == "mrope" else cfg.mrope_sections,
        remat_policy="none",
    )

"""SIRT — Simultaneous Iterative Reconstruction Technique.

x_{k+1} = x_k + lam * C (.) A^T [ R (.) (y - A x_k) ]

with R = 1/row-sums(A), C = 1/col-sums(A) computed matrix-free by projecting
constant images (the paper's memory-footprint point: the system matrix is
never materialized).  Relies on the *matched* A/A^T pair for convergence
stability over 1000+ iterations (paper §2.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projector import Projector

_EPS = 1e-6


def sirt(projector: Projector, y, n_iters: int = 50, x0=None, lam: float = 1.0,
         nonneg: bool = True, mask=None):
    """Reconstruct from sinogram ``y``.  ``mask`` (optional, same shape as y)
    restricts the data term to measured rays (limited-angle / few-view)."""
    geom = projector.geom
    ones_v = jnp.ones(geom.vol.shape, y.dtype)
    ones_s = jnp.ones(geom.sino_shape, y.dtype) if mask is None else mask
    row = projector(ones_v)                       # A 1
    col = projector.T(ones_s)                     # A^T 1 (masked)
    rinv = jnp.where(row > _EPS, 1.0 / jnp.maximum(row, _EPS), 0.0)
    cinv = jnp.where(col > _EPS, 1.0 / jnp.maximum(col, _EPS), 0.0)
    if mask is not None:
        rinv = rinv * mask
    x = jnp.zeros(geom.vol.shape, y.dtype) if x0 is None else x0

    def body(x, _):
        r = y - projector(x)
        if mask is not None:
            r = r * mask
        x = x + lam * cinv * projector.T(rinv * r)
        if nonneg:
            x = jnp.maximum(x, 0.0)
        return x, 0

    x, _ = jax.lax.scan(body, x, None, length=n_iters)
    return x

"""SIRT — Simultaneous Iterative Reconstruction Technique.

x_{k+1} = x_k + lam * C (.) A^T [ R (.) (y - A x_k) ]

with R = 1/row-sums(A), C = 1/col-sums(A) computed matrix-free by projecting
constant images (the paper's memory-footprint point: the system matrix is
never materialized).  Relies on the *matched* A/A^T pair for convergence
stability over 1000+ iterations (paper §2.1).

Accepts a ``ProjectorSpec``, a ``Projector`` or a
:class:`~repro.core.distributed.DistributedProjector`; leading batch dims on
``y`` are reconstructed jointly (every update is elementwise or routed
through the batch-aware projector), which is what the serving layer packs
onto the lane axis.  Under a distributed projector the loop runs unbatched
on the mesh: the per-sample residual reductions are over *global* (sharded)
sinogram axes, so XLA inserts the cross-shard reduction and the history
matches the single-device run.  Returns a
:class:`~repro.recon.result.ReconResult`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.recon.result import ReconResult, as_projector

_EPS = 1e-6

_IMG_AXES = (-3, -2, -1)


def _res_norm(r):
    """Per-sample data-residual norm over the 3 sinogram axes."""
    return jnp.sqrt(jnp.sum(jnp.square(r), axis=_IMG_AXES))


def sirt(spec_or_projector, y, n_iters: int = 50, x0=None, lam: float = 1.0,
         nonneg: bool = True, mask=None) -> ReconResult:
    """Reconstruct from sinogram ``y``.  ``mask`` (optional, broadcastable to
    y) restricts the data term to measured rays (limited-angle / few-view)."""
    projector = as_projector(spec_or_projector)
    geom = projector.geom
    batch_dims = y.shape[:-3]
    ones_v = jnp.ones(geom.vol.shape, y.dtype)
    ones_s = jnp.ones(geom.sino_shape, y.dtype) if mask is None else mask
    row = projector(ones_v)                       # A 1
    col = projector.T(ones_s)                     # A^T 1 (masked)
    rinv = jnp.where(row > _EPS, 1.0 / jnp.maximum(row, _EPS), 0.0)
    cinv = jnp.where(col > _EPS, 1.0 / jnp.maximum(col, _EPS), 0.0)
    if mask is not None:
        rinv = rinv * mask
    x = (jnp.zeros(batch_dims + geom.vol.shape, y.dtype)
         if x0 is None else x0)

    def body(x, _):
        r = y - projector(x)
        if mask is not None:
            r = r * mask
        x = x + lam * cinv * projector.T(rinv * r)
        if nonneg:
            x = jnp.maximum(x, 0.0)
        return x, _res_norm(r)

    x, hist = jax.lax.scan(body, x, None, length=n_iters)
    return ReconResult(image=x, iterations=n_iters,
                       residual_history=jnp.moveaxis(hist, 0, -1))

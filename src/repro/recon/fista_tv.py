"""FISTA with total-variation regularization:

    min_x  0.5 ||A x - y||^2 + beta * TV(x)

Gradient step through the matched pair (the gradient of the data term is
exactly A^T(Ax - y)); TV proximal step via the dual (Chambolle-style)
projection, a fixed small number of inner iterations.  The Lipschitz constant
of A^T A is estimated matrix-free by power iteration.

Accepts a ``ProjectorSpec`` or a ``Projector``.  All TV operators address
the trailing (nx, ny, nz) axes, so leading batch dims on ``y`` solve a
packed batch of independent problems (the momentum schedule t_k is
data-independent and shared).  Returns a
:class:`~repro.recon.result.ReconResult`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.recon.result import ReconResult, as_projector

_IMG_AXES = (-3, -2, -1)


def _pad_spec(ndim, axis, before, after):
    spec = [(0, 0)] * ndim
    spec[axis] = (before, after)
    return spec


def tv_norm(x):
    """Anisotropic TV over the trailing volume axes (per-sample for batches)."""
    dx = jnp.diff(x, axis=-3)
    dy = jnp.diff(x, axis=-2)
    out = (jnp.abs(dx).sum(axis=_IMG_AXES)
           + jnp.abs(dy).sum(axis=_IMG_AXES))
    if x.shape[-1] > 1:
        out = out + jnp.abs(jnp.diff(x, axis=-1)).sum(axis=_IMG_AXES)
    return out


def _grad_op(x):
    gx = jnp.pad(jnp.diff(x, axis=-3), _pad_spec(x.ndim, -3, 0, 1))
    gy = jnp.pad(jnp.diff(x, axis=-2), _pad_spec(x.ndim, -2, 0, 1))
    return gx, gy


def _div_op(px, py):
    dx = px - jnp.pad(px[..., :-1, :, :], _pad_spec(px.ndim, -3, 1, 0))
    dy = py - jnp.pad(py[..., :, :-1, :], _pad_spec(py.ndim, -2, 1, 0))
    return dx + dy


def tv_prox(x, weight, n_inner: int = 10):
    """prox_{weight * TV}(x) via dual projection (2D TV applied per z-slice)."""
    tau = 0.25

    def body(carry, _):
        px, py = carry
        gx, gy = _grad_op(_div_op(px, py) * weight - x / jnp.maximum(weight, 1e-12))
        # normalize dual step
        px = px - tau * gx
        py = py - tau * gy
        mag = jnp.maximum(1.0, jnp.sqrt(px ** 2 + py ** 2))
        return (px / mag, py / mag), 0

    p0 = (jnp.zeros_like(x), jnp.zeros_like(x))
    (px, py), _ = jax.lax.scan(body, p0, None, length=n_inner)
    return x - weight * _div_op(px, py)


def power_iteration(spec_or_projector, n_iters: int = 10, seed: int = 0):
    """Largest eigenvalue of A^T A (matrix-free)."""
    projector = as_projector(spec_or_projector)
    x = jax.random.normal(jax.random.PRNGKey(seed), projector.vol_shape())

    def body(x, _):
        z = projector.T(projector(x))
        nrm = jnp.linalg.norm(z.ravel())
        return z / jnp.maximum(nrm, 1e-30), nrm

    x, hist = jax.lax.scan(body, x, None, length=n_iters)
    return hist[-1]


def fista_tv(spec_or_projector, y, n_iters: int = 50, beta: float = 1e-3,
             x0=None, mask=None, L=None, nonneg: bool = True,
             tv_inner: int = 10) -> ReconResult:
    projector = as_projector(spec_or_projector)
    if L is None:
        # The Lipschitz constant of A^T A is a property of the operator, not
        # the data — one unbatched power iteration covers a packed batch.
        L = power_iteration(projector) * 1.05
    step = 1.0 / L
    batch_dims = y.shape[:-3]
    x = (jnp.zeros(batch_dims + projector.vol_shape(), y.dtype)
         if x0 is None else x0)
    z, t = x, jnp.asarray(1.0, y.dtype)

    def body(carry, _):
        x, z, t = carry
        r = projector(z) - y
        if mask is not None:
            r = r * mask
        g = projector.T(r)
        xn = tv_prox(z - step * g, beta * step, tv_inner)
        if nonneg:
            xn = jnp.maximum(xn, 0.0)
        tn = (1.0 + jnp.sqrt(1.0 + 4.0 * t * t)) / 2.0
        zn = xn + ((t - 1.0) / tn) * (xn - x)
        return (xn, zn, tn), jnp.sqrt(jnp.sum(jnp.square(r), axis=_IMG_AXES))

    (x, _, _), hist = jax.lax.scan(body, (x, z, t), None, length=n_iters)
    return ReconResult(image=x, iterations=n_iters,
                       residual_history=jnp.moveaxis(hist, 0, -1))

"""FISTA with total-variation regularization:

    min_x  0.5 ||A x - y||^2 + beta * TV(x)

Gradient step through the matched pair (the gradient of the data term is
exactly A^T(Ax - y)); TV proximal step via the dual (Chambolle-style)
projection, a fixed small number of inner iterations.  The Lipschitz constant
of A^T A is estimated matrix-free by power iteration.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projector import Projector


def tv_norm(x):
    dx = jnp.diff(x, axis=0)
    dy = jnp.diff(x, axis=1)
    dz = jnp.diff(x, axis=2) if x.shape[2] > 1 else jnp.zeros_like(x[:, :, :0])
    return (jnp.abs(dx).sum() + jnp.abs(dy).sum()
            + (jnp.abs(dz).sum() if dz.size else 0.0))


def _grad_op(x):
    gx = jnp.pad(jnp.diff(x, axis=0), ((0, 1), (0, 0), (0, 0)))
    gy = jnp.pad(jnp.diff(x, axis=1), ((0, 0), (0, 1), (0, 0)))
    return gx, gy


def _div_op(px, py):
    dx = px - jnp.pad(px[:-1], ((1, 0), (0, 0), (0, 0)))
    dy = py - jnp.pad(py[:, :-1], ((0, 0), (1, 0), (0, 0)))
    return dx + dy


def tv_prox(x, weight, n_inner: int = 10):
    """prox_{weight * TV}(x) via dual projection (2D TV applied per z-slice)."""
    tau = 0.25

    def body(carry, _):
        px, py = carry
        gx, gy = _grad_op(_div_op(px, py) * weight - x / jnp.maximum(weight, 1e-12))
        # normalize dual step
        px = px - tau * gx
        py = py - tau * gy
        mag = jnp.maximum(1.0, jnp.sqrt(px ** 2 + py ** 2))
        return (px / mag, py / mag), 0

    p0 = (jnp.zeros_like(x), jnp.zeros_like(x))
    (px, py), _ = jax.lax.scan(body, p0, None, length=n_inner)
    return x - weight * _div_op(px, py)


def power_iteration(projector: Projector, n_iters: int = 10, seed: int = 0):
    """Largest eigenvalue of A^T A (matrix-free)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), projector.vol_shape())

    def body(x, _):
        z = projector.T(projector(x))
        nrm = jnp.linalg.norm(z.ravel())
        return z / jnp.maximum(nrm, 1e-30), nrm

    x, hist = jax.lax.scan(body, x, None, length=n_iters)
    return hist[-1]


def fista_tv(projector: Projector, y, n_iters: int = 50, beta: float = 1e-3,
             x0=None, mask=None, L=None, nonneg: bool = True,
             tv_inner: int = 10):
    if L is None:
        L = power_iteration(projector) * 1.05
    step = 1.0 / L
    x = jnp.zeros(projector.vol_shape(), y.dtype) if x0 is None else x0
    z, t = x, jnp.asarray(1.0, y.dtype)

    def body(carry, _):
        x, z, t = carry
        r = projector(z) - y
        if mask is not None:
            r = r * mask
        g = projector.T(r)
        xn = tv_prox(z - step * g, beta * step, tv_inner)
        if nonneg:
            xn = jnp.maximum(xn, 0.0)
        tn = (1.0 + jnp.sqrt(1.0 + 4.0 * t * t)) / 2.0
        zn = xn + ((t - 1.0) / tn) * (xn - x)
        return (xn, zn, tn), 0

    (x, _, _), _ = jax.lax.scan(body, (x, z, t), None, length=n_iters)
    return x

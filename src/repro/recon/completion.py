"""Sinogram completion + data-consistency refinement (paper §3/§4).

The paper's inference-time pipeline for limited-angle CT:

1. a trained network predicts a volume  x_net  from the ill-posed input;
2. the *measured* views are kept and the missing views are filled from the
   forward projection of the prediction (``complete_sinogram``);
3. an iterative data-consistency step refines the volume against the
   measured data while staying close to the network prior:

       min_x  0.5 || M (A x - y) ||^2  +  0.5 * beta || x - x_net ||^2

   solved by CG (the objective is quadratic; gradients use the matched pair).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.recon.result import as_projector


def data_consistency_refine(spec_or_projector, x_net, y, mask,
                            n_iters: int = 20, beta: float = 0.1):
    """CG on  (A^T M A + beta I) x = A^T M y + beta x_net."""
    projector = as_projector(spec_or_projector)

    def op(x):
        return projector.T(mask * projector(x)) + beta * x

    b = projector.T(mask * y) + beta * x_net
    x = x_net
    r = b - op(x)
    p = r
    rs = jnp.vdot(r, r).real

    def body(carry, _):
        x, r, p, rs = carry
        q = op(p)
        alpha = rs / jnp.maximum(jnp.vdot(p, q).real, 1e-30)
        x = x + alpha * p
        r = r - alpha * q
        rs_new = jnp.vdot(r, r).real
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return (x, r, p, rs_new), rs_new

    (x, _, _, _), _ = jax.lax.scan(body, (x, r, p, rs), None, length=n_iters)
    return x


def complete_and_refine(spec_or_projector, x_net, y, mask,
                        n_iters: int = 20, beta: float = 0.1):
    """Full paper §4 inference pipeline.  Returns (x_refined, completed_sino)."""
    projector = as_projector(spec_or_projector)
    x = data_consistency_refine(projector, x_net, y, mask, n_iters, beta)
    completed = mask * y + (1.0 - mask) * projector(x)
    return x, completed


def projection_residual(spec_or_projector, x, y, mask=None):
    """Relative projection-consistency residual ``||M (A x - y)|| / ||M y||``.

    The scale-free companion of :meth:`Projector.data_consistency`: a value
    of 0 means the reconstruction explains every measured view exactly, 1
    means it explains nothing — comparable across geometries and phantom
    scales, which is what the per-geometry quality gate needs."""
    projector = as_projector(spec_or_projector)
    r = projector(x) - y
    if mask is not None:
        r = r * mask
        y = y * mask
    num = jnp.sqrt(jnp.sum(jnp.square(r)))
    den = jnp.maximum(jnp.sqrt(jnp.sum(jnp.square(y))), 1e-12)
    return num / den

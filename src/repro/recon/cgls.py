"""CGLS — conjugate gradient on the normal equations A^T A x = A^T y.

Mathematically requires the backprojector to be the *exact* adjoint of the
forward projector; with unmatched pairs CG diverges (Zeng & Gullberg 2000) —
this is exactly the paper's argument for matched pairs.  Supports Tikhonov
damping: min ||Ax - y||^2 + damp ||x||^2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.projector import Projector


def cgls(projector: Projector, y, n_iters: int = 30, x0=None,
         damp: float = 0.0, mask=None):
    A = (lambda x: projector(x) * mask) if mask is not None else projector
    AT = (lambda r: projector.T(r * mask)) if mask is not None else projector.T

    x = jnp.zeros(projector.vol_shape(), y.dtype) if x0 is None else x0
    r = y - A(x)
    if mask is not None:
        r = r * mask
    s = AT(r) - damp * x
    p = s
    gamma = jnp.vdot(s, s).real

    def body(carry, _):
        x, r, p, gamma = carry
        q = A(p)
        delta = jnp.vdot(q, q).real + damp * jnp.vdot(p, p).real
        alpha = gamma / jnp.maximum(delta, 1e-30)
        x = x + alpha * p
        r = r - alpha * q
        s = AT(r) - damp * x
        gamma_new = jnp.vdot(s, s).real
        beta = gamma_new / jnp.maximum(gamma, 1e-30)
        p = s + beta * p
        return (x, r, p, gamma_new), gamma_new

    (x, _, _, _), hist = jax.lax.scan(body, (x, r, p, gamma), None,
                                      length=n_iters)
    return x, hist

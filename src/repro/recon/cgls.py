"""CGLS — conjugate gradient on the normal equations A^T A x = A^T y.

Mathematically requires the backprojector to be the *exact* adjoint of the
forward projector; with unmatched pairs CG diverges (Zeng & Gullberg 2000) —
this is exactly the paper's argument for matched pairs.  Supports Tikhonov
damping: min ||Ax - y||^2 + damp ||x||^2.

Accepts a ``ProjectorSpec``, a ``Projector`` or a
:class:`~repro.core.distributed.DistributedProjector`.  Leading batch dims
on ``y`` run independent CG iterations side by side: every inner product
reduces over the trailing image/sinogram axes only (keepdims, so the
per-sample step sizes broadcast), which keeps a packed serving batch
mathematically identical to solving each request alone.  The same
reductions stay correct under a distributed projector — they run on global
(sharded) arrays, so the CG scalars are mesh-wide inner products, exactly
as CG requires.  Returns a :class:`~repro.recon.result.ReconResult`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.recon.result import ReconResult, as_projector

_IMG_AXES = (-3, -2, -1)


def _dot(a, b):
    """Per-sample inner product over the 3 trailing axes, kept broadcastable."""
    return jnp.sum(a * b, axis=_IMG_AXES, keepdims=True)


def cgls(spec_or_projector, y, n_iters: int = 30, x0=None,
         damp: float = 0.0, mask=None) -> ReconResult:
    projector = as_projector(spec_or_projector)
    A = (lambda x: projector(x) * mask) if mask is not None else projector
    AT = (lambda r: projector.T(r * mask)) if mask is not None else projector.T

    batch_dims = y.shape[:-3]
    x = (jnp.zeros(batch_dims + projector.vol_shape(), y.dtype)
         if x0 is None else x0)
    r = y - A(x)
    if mask is not None:
        r = r * mask
    s = AT(r) - damp * x
    p = s
    gamma = _dot(s, s)

    def body(carry, _):
        x, r, p, gamma = carry
        q = A(p)
        delta = _dot(q, q) + damp * _dot(p, p)
        alpha = gamma / jnp.maximum(delta, 1e-30)
        x = x + alpha * p
        r = r - alpha * q
        s = AT(r) - damp * x
        gamma_new = _dot(s, s)
        beta = gamma_new / jnp.maximum(gamma, 1e-30)
        p = s + beta * p
        res = jnp.sqrt(jnp.sum(jnp.square(r), axis=_IMG_AXES))
        return (x, r, p, gamma_new), res

    (x, _, _, _), hist = jax.lax.scan(body, (x, r, p, gamma), None,
                                      length=n_iters)
    return ReconResult(image=x, iterations=n_iters,
                       residual_history=jnp.moveaxis(hist, 0, -1))

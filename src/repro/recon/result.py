"""Uniform solver result + input coercion for the recon layer.

Every iterative solver (``sirt`` / ``cgls`` / ``fista_tv``) returns a
:class:`ReconResult` and accepts either a :class:`~repro.core.spec.ProjectorSpec`
or an already-built :class:`~repro.core.projector.Projector` — the serving
layer hands specs straight through, interactive code keeps its Projector.

``ReconResult`` is registered as a JAX pytree (``image`` and
``residual_history`` are leaves, ``iterations`` is static aux data), so a
solver closure returning one can be ``jax.jit``-ed and vmapped as-is — this
is what lets the serving executors compile whole solver calls per bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.projector import Projector
from repro.core.spec import ProjectorSpec

__all__ = ["ReconResult", "as_projector"]


@dataclasses.dataclass(frozen=True)
class ReconResult:
    """What an iterative solver hands back.

    Attributes:
        image:            the reconstruction; leading batch dims (if the
                          sinogram had any) are preserved.
        iterations:       number of outer iterations run (static).
        residual_history: per-iteration data-residual norm ``||A x_k - y||``
                          (masked where a mask was given), shape
                          ``batch_dims + (iterations,)``.
    """

    image: Any
    iterations: int
    residual_history: Any

    @property
    def final_residual(self):
        return self.residual_history[..., -1]


def _flatten(r: ReconResult):
    return (r.image, r.residual_history), r.iterations


def _unflatten(iterations, children):
    image, residual_history = children
    return ReconResult(image=image, iterations=iterations,
                       residual_history=residual_history)


jax.tree_util.register_pytree_node(ReconResult, _flatten, _unflatten)


def as_projector(spec_or_projector):
    """Coerce a solver's operator argument to a projector object.

    Specs are the canonical currency (hashable, bucketable); a prebuilt
    :class:`Projector` passes through so repeated solves reuse its spec.
    A :class:`~repro.core.distributed.DistributedProjector` also passes
    through (it quacks the same: ``geom``/``__call__``/``T``), and a spec
    carrying a :class:`~repro.core.spec.ShardSpec` is realized on the mesh
    of its devices — so the iterative solvers run distributed without
    solver forks."""
    from repro.core.distributed import DistributedProjector
    if isinstance(spec_or_projector, (Projector, DistributedProjector)):
        return spec_or_projector
    if isinstance(spec_or_projector, ProjectorSpec):
        if spec_or_projector.shard is not None:
            raise ValueError(
                "this ProjectorSpec carries a ShardSpec, which needs a "
                "device mesh to realize — build "
                "DistributedProjector(spec, mesh) and pass that to the "
                "solver instead")
        return Projector(spec_or_projector)
    raise TypeError(
        f"expected a ProjectorSpec, Projector or DistributedProjector, "
        f"got {type(spec_or_projector).__name__}")

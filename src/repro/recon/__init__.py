"""Classical + hybrid reconstruction algorithms built on the matched
projector pairs — the paper's 'end-to-end reconstruction pipeline' layer.

All iterative solvers accept a ``ProjectorSpec`` or ``Projector`` and
return a :class:`~repro.recon.result.ReconResult`."""
from repro.recon.result import ReconResult, as_projector
from repro.recon.sirt import sirt
from repro.recon.cgls import cgls
from repro.recon.fista_tv import fista_tv, tv_norm
from repro.recon.completion import (complete_and_refine,
                                    data_consistency_refine,
                                    projection_residual)

__all__ = ["ReconResult", "as_projector", "sirt", "cgls", "fista_tv",
           "tv_norm", "complete_and_refine", "data_consistency_refine",
           "projection_residual"]

"""Synthetic token stream for exercising the LM-architecture configs.

Deterministic function of (seed, step, shard) like the CT pipeline; tokens are
Zipf-distributed with a repeating-ngram structure so the loss is learnable
(useful for the smoke-training examples)."""
from __future__ import annotations

import numpy as np


class TokenPipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard_index: int = 0, shard_count: int = 1,
                 start_step: int = 0):
        if global_batch % shard_count:
            raise ValueError(f"global_batch={global_batch} must be divisible "
                             f"by shard_count={shard_count} so every data "
                             f"shard gets an equal local batch")
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.local_batch = global_batch // shard_count
        self.seed = seed
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.step = start_step

    def batch(self, step: int = None) -> np.ndarray:
        step = self.step if step is None else step
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard_index]))
        b, t, v = self.local_batch, self.seq_len, self.vocab_size
        # zipf-ish marginal over a capped alphabet + copied spans
        probs = 1.0 / np.arange(1, min(v, 4096) + 1) ** 1.1
        probs /= probs.sum()
        toks = rng.choice(len(probs), size=(b, t), p=probs).astype(np.int32)
        # repeat a prefix span to give the model something to learn
        span = max(4, t // 16)
        toks[:, span:2 * span] = toks[:, :span]
        return toks % v

    def __iter__(self):
        while True:
            yield self.batch()
            self.step += 1

    def state_dict(self):
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d):
        self.step = int(d["step"])

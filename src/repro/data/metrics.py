"""Image quality metrics (PSNR / SSIM) used by the paper's §4 evaluation."""
from __future__ import annotations

import numpy as np
from scipy.ndimage import uniform_filter


def psnr(x, ref, peak: float = None) -> float:
    x, ref = np.asarray(x, np.float64), np.asarray(ref, np.float64)
    peak = float(ref.max() - ref.min()) if peak is None else peak
    mse = float(np.mean((x - ref) ** 2))
    return 10.0 * np.log10(peak ** 2 / max(mse, 1e-20))


def ssim(x, ref, peak: float = None, win: int = 7) -> float:
    """Mean SSIM with a uniform window (Wang et al. 2004 simplified)."""
    x, ref = np.asarray(x, np.float64), np.asarray(ref, np.float64)
    peak = float(ref.max() - ref.min()) if peak is None else peak
    c1, c2 = (0.01 * peak) ** 2, (0.03 * peak) ** 2
    mu_x = uniform_filter(x, win)
    mu_y = uniform_filter(ref, win)
    sxx = uniform_filter(x * x, win) - mu_x ** 2
    syy = uniform_filter(ref * ref, win) - mu_y ** 2
    sxy = uniform_filter(x * ref, win) - mu_x * mu_y
    num = (2 * mu_x * mu_y + c1) * (2 * sxy + c2)
    den = (mu_x ** 2 + mu_y ** 2 + c1) * (sxx + syy + c2)
    return float(np.mean(num / den))

from repro.data.phantoms import (random_ellipse_phantom, shepp_logan_2d,
                                 analytic_parallel_projection)
from repro.data.pipeline import CTDataPipeline
from repro.data.tokens import TokenPipeline

__all__ = ["random_ellipse_phantom", "shepp_logan_2d",
           "analytic_parallel_projection", "CTDataPipeline", "TokenPipeline"]

"""Deterministic, shardable, prefetching data pipelines.

Production posture: every batch is a pure function of (seed, step, shard), so
* restarting from a checkpoint replays the stream exactly (fault tolerance);
* each data-parallel host generates only its shard (no central bottleneck);
* a background thread keeps one batch ahead of the consumer.
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.core.geometry import CTGeometry
from repro.data import phantoms


class _Prefetcher:
    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


class CTDataPipeline:
    """Generates (phantom_volume, full_sinogram, mask) training batches for the
    limited-angle / few-view experiments (paper §4).

    The mask randomizes the available angular range per sample — the paper's
    'augment diverse ill-posed inputs given the training projection data'.
    """

    def __init__(self, geom: CTGeometry, batch_size: int, seed: int = 0,
                 mode: str = "limited_angle", available_deg: float = 60.0,
                 n_views_few: int = 32, shard_index: int = 0,
                 shard_count: int = 1, start_step: int = 0):
        if batch_size % shard_count:
            raise ValueError(f"batch_size={batch_size} must be divisible by "
                             f"shard_count={shard_count} so every data shard "
                             f"gets an equal local batch")
        self.geom = geom
        self.global_batch = batch_size
        self.local_batch = batch_size // shard_count
        self.seed = seed
        self.mode = mode
        self.available_deg = available_deg
        self.n_views_few = n_views_few
        self.shard_index = shard_index
        self.shard_count = shard_count
        self.step = start_step

    # -- deterministic per-(step, sample) RNG ------------------------------- #
    def _rng(self, step: int, sample: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, sample]))

    def make_mask(self, rng: np.random.Generator) -> np.ndarray:
        na = self.geom.n_angles
        mask = np.zeros((na,), np.float32)
        if self.mode == "limited_angle":
            n_avail = int(round(na * self.available_deg / 180.0))
            start = int(rng.integers(0, na))
            idx = (start + np.arange(n_avail)) % na
            mask[idx] = 1.0
        elif self.mode == "few_view":
            idx = rng.choice(na, size=self.n_views_few, replace=False)
            mask[idx] = 1.0
        else:
            mask[:] = 1.0
        return mask

    def sample(self, step: int, sample_id: int):
        """One (phantom, view_mask) pair.  2D geometries (``vol.nz == 1``)
        get an ``(nx, ny)`` slice; volumetric geometries (helical scans) get
        an ``(nx, ny, nz)`` volume that interpolates between two independent
        ellipse keyframes along z — real axial structure for the cost of two
        rasterizations, so the z-travelling helical rays see a non-trivial
        object."""
        rng = self._rng(step, sample_id)
        vol = self.geom.vol
        if vol.nz == 1:
            img, _ = phantoms.random_ellipse_phantom(
                int(rng.integers(0, 2 ** 31)), vol)
        else:
            lo, _ = phantoms.random_ellipse_phantom(
                int(rng.integers(0, 2 ** 31)), vol)
            hi, _ = phantoms.random_ellipse_phantom(
                int(rng.integers(0, 2 ** 31)), vol)
            t = (np.arange(vol.nz, dtype=np.float32)
                 / max(vol.nz - 1, 1))[None, None, :]
            img = lo[:, :, None] * (1.0 - t) + hi[:, :, None] * t
        img = img * 0.02  # plausible attenuation scale (1/mm)
        mask = self.make_mask(rng)
        return img.astype(np.float32), mask

    def batch(self, step: int):
        """Local shard of the global batch for `step`."""
        ids = (self.shard_index * self.local_batch
               + np.arange(self.local_batch))
        imgs, masks = zip(*(self.sample(step, int(i)) for i in ids))
        return np.stack(imgs), np.stack(masks)

    def __iter__(self):
        def gen():
            while True:
                b = self.batch(self.step)
                self.step += 1
                yield b
        return iter(_Prefetcher(gen()))

    # -- checkpointable state ------------------------------------------------ #
    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict):
        if d["seed"] != self.seed:
            raise ValueError(f"data seed mismatch on restore: checkpoint has "
                             f"seed={d['seed']}, pipeline was built with "
                             f"seed={self.seed}; restoring would silently "
                             f"replay a different data stream")
        self.step = int(d["step"])

"""Synthetic phantoms with *analytic* parallel-beam projections.

The paper's experiments use an airport-luggage dataset that is not
redistributable; the protocol is reproduced on randomized ellipse phantoms
(the standard CT stand-in).  Ellipses also give closed-form line integrals,
which we use as ground truth for the quantitative-accuracy tests:

    p(phi, u) = 2 rho A B sqrt(w^2 - tau^2) / w^2,
    w^2 = A'^2 sin^2(phi-alpha)... (rotated form below)
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.geometry import VolumeGeometry


@dataclasses.dataclass(frozen=True)
class Ellipse:
    cx: float
    cy: float
    a: float       # semi-axis along (rotated) x
    b: float       # semi-axis along (rotated) y
    angle: float   # rotation, radians
    rho: float     # density (1/mm)


SHEPP_LOGAN = (
    Ellipse(0.0, 0.0, 0.69, 0.92, 0.0, 1.0),
    Ellipse(0.0, -0.0184, 0.6624, 0.874, 0.0, -0.8),
    Ellipse(0.22, 0.0, 0.11, 0.31, np.deg2rad(-18), -0.2),
    Ellipse(-0.22, 0.0, 0.16, 0.41, np.deg2rad(18), -0.2),
    Ellipse(0.0, 0.35, 0.21, 0.25, 0.0, 0.1),
    Ellipse(0.0, 0.1, 0.046, 0.046, 0.0, 0.1),
    Ellipse(0.0, -0.1, 0.046, 0.046, 0.0, 0.1),
    Ellipse(-0.08, -0.605, 0.046, 0.023, 0.0, 0.1),
    Ellipse(0.0, -0.605, 0.023, 0.023, 0.0, 0.1),
    Ellipse(0.06, -0.605, 0.023, 0.046, 0.0, 0.1),
)


def rasterize(ellipses: Sequence[Ellipse], vol: VolumeGeometry,
              supersample: int = 1) -> np.ndarray:
    """(nx, ny) image of summed densities (antialiased via supersampling)."""
    ss = supersample
    nx, ny = vol.nx * ss, vol.ny * ss
    xs = (np.arange(nx) - (nx - 1) / 2.0) * (vol.dx / ss) + vol.offset_x
    ys = (np.arange(ny) - (ny - 1) / 2.0) * (vol.dy / ss) + vol.offset_y
    X, Y = np.meshgrid(xs, ys, indexing="ij")
    img = np.zeros((nx, ny), np.float32)
    for e in ellipses:
        ca, sa = np.cos(e.angle), np.sin(e.angle)
        xr = (X - e.cx) * ca + (Y - e.cy) * sa
        yr = -(X - e.cx) * sa + (Y - e.cy) * ca
        img += e.rho * (((xr / e.a) ** 2 + (yr / e.b) ** 2) <= 1.0)
    if ss > 1:
        img = img.reshape(vol.nx, ss, vol.ny, ss).mean(axis=(1, 3))
    return img


def analytic_parallel_projection(ellipses: Sequence[Ellipse],
                                 angles: np.ndarray,
                                 us: np.ndarray) -> np.ndarray:
    """Exact line integrals, shape (n_angles, n_u).

    Detector coordinate convention matches the library: the ray at angle phi,
    detector coordinate u, has direction (cos phi, sin phi) and passes
    through u * (-sin phi, cos phi)."""
    out = np.zeros((len(angles), len(us)), np.float32)
    for e in ellipses:
        for ia, phi in enumerate(angles):
            # center's detector coordinate
            uc = e.cy * np.cos(phi) - e.cx * np.sin(phi)
            # ellipse rotated by `angle`: effective half-width along u-axis
            t = phi - e.angle
            w2 = (e.a * np.sin(t)) ** 2 + (e.b * np.cos(t)) ** 2
            tau = us - uc
            inside = np.maximum(w2 - tau ** 2, 0.0)
            out[ia] += (2.0 * e.rho * e.a * e.b / w2) * np.sqrt(inside)
    return out


def shepp_logan_2d(vol: VolumeGeometry, scale_mm: float = None,
                   supersample: int = 2) -> np.ndarray:
    """Shepp-Logan phantom scaled to the volume's extent."""
    s = scale_mm or 0.48 * min(vol.nx * vol.dx, vol.ny * vol.dy)
    ells = [dataclasses.replace(e, cx=e.cx * s, cy=e.cy * s,
                                a=e.a * s, b=e.b * s) for e in SHEPP_LOGAN]
    return rasterize(ells, vol, supersample)


def random_ellipses(rng: np.random.Generator, vol: VolumeGeometry,
                    n_min: int = 4, n_max: int = 10) -> list:
    """Random ellipse set inside the volume's inscribed circle."""
    R = 0.45 * min(vol.nx * vol.dx, vol.ny * vol.dy)
    n = int(rng.integers(n_min, n_max + 1))
    ells = []
    for _ in range(n):
        r = R * np.sqrt(rng.uniform(0, 0.8))
        th = rng.uniform(0, 2 * np.pi)
        ells.append(Ellipse(
            cx=r * np.cos(th), cy=r * np.sin(th),
            a=rng.uniform(0.05, 0.35) * R, b=rng.uniform(0.05, 0.35) * R,
            angle=rng.uniform(0, np.pi), rho=float(rng.uniform(0.2, 1.0))))
    return ells


def random_ellipse_phantom(seed: int, vol: VolumeGeometry,
                           supersample: int = 2):
    """Returns (image (nx, ny), ellipses) for a deterministic seed."""
    rng = np.random.default_rng(seed)
    ells = random_ellipses(rng, vol)
    return rasterize(ells, vol, supersample), ells

"""repro-lint: AST-level invariant checker for the kernel-suite contracts.

Every PR in this repo has added cross-file invariants that plain unit tests
cannot see breaking until a refactor lands on TPU hardware or a jax upgrade
hits CI: the f32-accumulator policy inside Pallas kernels, the
``repro.compat`` drift firewall, the content-stable hashing rules behind the
serving cache, the CTServer warm-path compile guarantee, the matched
FP/BP/oracle registry, and the benchmark-gate row inventory.  ``repro.lint``
turns each of those into a named, explainable, suppressible rule:

    RL001  f32 accumulator policy in ``kernels/fp_*.py``
    RL002  no bare ``assert`` in library code
    RL003  version-drift jax APIs only via ``repro.compat``
    RL004  hash-unstable constructs in spec/geometry identity paths
    RL005  no compile triggers on the CTServer request path
    RL006  kernel registry completeness (BP + oracle + tune + adjoint test)
    RL007  benchmark rows vs ``baseline.json`` / ci.yml consistency

Usage::

    PYTHONPATH=src python -m repro.lint src tests benchmarks
    PYTHONPATH=src python -m repro.lint --explain RL004

Suppress a single diagnostic with a same-line pragma (justify it next to
the code)::

    some_violation()   # repro-lint: disable=RL004

Implementation is stdlib-``ast`` only (plus one deliberate import of the
live kernel registry for RL006 — a registry can only be introspected, not
parsed).  See ``docs/INVARIANTS.md`` for the contract behind each rule.
"""
from repro.lint.engine import Diagnostic, Project, collect, run_rules

__all__ = ["Diagnostic", "Project", "collect", "run_rules"]

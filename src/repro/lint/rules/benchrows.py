"""RL007 — benchmark row inventory vs baseline.json vs ci.yml.

Single source of truth: ``benchmarks.check_regression.expected_rows()``
(exposed on the CLI as ``--list-expected-rows``) — this rule and the CI
smoke job both consume it instead of keeping hand-maintained row lists.
"""
from __future__ import annotations

import ast
import importlib
import pathlib
import re
import sys
from typing import List, Tuple

from repro.lint.engine import Diagnostic, Project

CODE = "RL007"
NAME = "bench-rows"
EXPLAIN = """\
RL007 (bench-rows): the benchmark regression gate only fails on rows the
committed baseline knows about — a *new* bench row that never gets added
to benchmarks/baseline.json is a silent WARN forever, and a baseline row
whose bench was renamed is dead weight that fails every future run.  This
rule closes the loop statically:

  * every gated row a benchmark can emit (csv_rows.append literals, with
    f-string placeholders widened to a wildcard) must appear in
    baseline.json when it matches a gated prefix (kernel/fp|bp, serve/,
    dist/, quality/) — run the suite and --write-baseline to add it;
  * every baseline row must be producible by some csv_rows.append site —
    otherwise the gate is checking a renamed/removed bench;
  * ci.yml must assert row presence via
    `check_regression --list-expected-rows <prefix>` (or grep every
    expected row literally) for each gated suite it smokes.

Gated prefixes and the expected-row list are imported from
benchmarks.check_regression — there is exactly one place to edit.
"""

_APPEND_TARGET = "csv_rows"


def _fstring_regex(node: ast.JoinedStr) -> str:
    parts = []
    for v in node.values:
        if isinstance(v, ast.Constant):
            parts.append(re.escape(str(v.value)))
        else:
            parts.append(r"[^,]+")
    return "".join(parts)


def _emitted(root: pathlib.Path) -> List[Tuple[str, int, str, bool]]:
    """(file, line, row-pattern, is_literal) for every csv_rows.append."""
    out: List[Tuple[str, int, str, bool]] = []
    for path in sorted((root / "benchmarks").glob("*.py")):
        try:
            tree = ast.parse(path.read_text(encoding="utf-8",
                                            errors="replace"))
        except SyntaxError:
            continue  # reported as RL000 when the file is scanned
        display = f"benchmarks/{path.name}"
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "append"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == _APPEND_TARGET
                    and node.args
                    and isinstance(node.args[0], ast.Tuple)
                    and node.args[0].elts):
                continue
            first = node.args[0].elts[0]
            if isinstance(first, ast.Constant) and isinstance(first.value,
                                                              str):
                out.append((display, node.lineno, first.value, True))
            elif isinstance(first, ast.JoinedStr):
                out.append((display, node.lineno, _fstring_regex(first),
                            False))
    return out


def check(project: Project) -> List[Diagnostic]:
    root = project.root
    cr_path = root / "benchmarks" / "check_regression.py"
    if not cr_path.exists():
        return []
    sys.path.insert(0, str(root))
    try:
        cr = importlib.import_module("benchmarks.check_regression")
    except Exception as e:  # pragma: no cover - environment failure
        return [Diagnostic(CODE, "benchmarks/check_regression.py", 1,
                           f"could not import benchmarks.check_regression "
                           f"for the expected-row list: {e}")]
    finally:
        sys.path.remove(str(root))

    expected = set(cr.expected_rows())
    gates = (cr.GATE, cr.SERVE_GATE, cr.DIST_GATE, cr.QUALITY_GATE)
    emitted = _emitted(root)
    diags: List[Diagnostic] = []

    # 1) gated emitted literals must be in the baseline
    for display, line, pattern, is_literal in emitted:
        if not is_literal:
            continue
        if any(g.match(pattern) for g in gates) and pattern not in expected:
            diags.append(Diagnostic(
                CODE, display, line,
                f"bench row {pattern!r} matches a gated prefix but is not "
                f"in benchmarks/baseline.json — the regression gate only "
                f"WARNs on unknown rows, so this row is silently ungated "
                f"(run the suite and --write-baseline)"))

    # 2) every baseline row must be producible by some append site
    literals = {p for _, _, p, lit in emitted if lit}
    regexes = [re.compile(p + r"\Z") for _, _, p, lit in emitted if not lit]
    for row in sorted(expected):
        if row in literals or any(r.match(row) for r in regexes):
            continue
        diags.append(Diagnostic(
            CODE, "benchmarks/baseline.json", 1,
            f"baseline row {row!r} is not emitted by any csv_rows.append "
            f"in benchmarks/ — a renamed or removed bench would fail "
            f"every future gate run (regenerate the baseline)"))

    # 3) ci.yml must consume the expected-row list per gated suite
    ci_path = root / ".github" / "workflows" / "ci.yml"
    if ci_path.exists():
        ci = ci_path.read_text(encoding="utf-8", errors="replace")
        for prefix in cr.GATED_PREFIXES:
            rows = [r for r in expected if r.startswith(prefix)]
            if not rows:
                continue
            uses_list = "--list-expected-rows" in ci and prefix in ci
            if uses_list or all(r in ci for r in rows):
                continue
            missing = [r for r in rows if r not in ci]
            diags.append(Diagnostic(
                CODE, ".github/workflows/ci.yml", 1,
                f"CI does not assert the {prefix}* bench rows — use "
                f"`check_regression --list-expected-rows {prefix}` in the "
                f"smoke job ({len(missing)} expected rows unchecked, e.g. "
                f"{missing[0]!r})"))
    return diags

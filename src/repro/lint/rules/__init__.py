"""Rule registry: one module per RL code."""
from repro.lint.rules import (accumulator, asserts, benchrows, drift,
                              hashing, registry, warmpath)

ALL_RULES = (accumulator, asserts, drift, hashing, warmpath, registry,
             benchrows)


def by_code(code: str):
    for rule in ALL_RULES:
        if rule.CODE == code.upper():
            return rule
    return None

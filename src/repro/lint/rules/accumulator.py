"""RL001 — f32 accumulator policy in the forward/back-projection kernels.

Scope: every ``src/repro/kernels/fp_*.py`` file (the matched Pallas FP/BP
pairs).  ``kernels/flash.py`` is deliberately out of scope: its
``pallas_call`` out_shapes carry the *input* dtype because its f32
accumulators live in ``scratch_shapes`` — a different, equally valid
spelling of the same policy.
"""
from __future__ import annotations

import ast
from typing import List

from repro.lint.astutil import ImportMap, keyword_arg, resolve
from repro.lint.engine import Diagnostic, Project

CODE = "RL001"
NAME = "f32-accumulator"
EXPLAIN = """\
RL001 (f32-accumulator): mixed-precision kernels must accumulate in f32.

The bf16 tile policy (PR 6, kernels/precision.py) stores projection inputs
in bf16 but requires every MXU contraction and every cross-grid-step
accumulator to be float32, or the adjoint dot-test drifts past tolerance:

  * every jax.lax.dot_general / jnp.dot / pl.dot inside kernels/fp_*.py
    must pass preferred_element_type=jnp.float32;
  * every pl.pallas_call out_shape in those files must be a
    jax.ShapeDtypeStruct with dtype jnp.float32 — the out_ref is the
    cross-view-group accumulator, so its dtype IS the accumulator dtype.

kernels/flash.py is exempt by scope: its accumulators are f32
scratch_shapes and its outputs intentionally match the input dtype.

Fix: add preferred_element_type=jnp.float32 to the contraction, or make the
out_shape dtype jnp.float32 and downcast after the pallas_call returns.
Suppress (rare — e.g. an intentionally integer-typed index-map output) with
`# repro-lint: disable=RL001` on the flagged line.
"""

_DOT_FUNCS = {
    "jax.lax.dot_general",
    "jax.lax.dot",
    "jax.numpy.dot",
    "jax.numpy.matmul",
    "jax.experimental.pallas.dot",
}
_PALLAS_CALL = "jax.experimental.pallas.pallas_call"
_F32 = "jax.numpy.float32"


def _is_f32(node: ast.expr, imports: ImportMap) -> bool:
    return node is not None and resolve(node, imports) == _F32


def _check_struct(call: ast.expr, imports: ImportMap, path: str,
                  diags: List[Diagnostic]) -> None:
    """One element of an out_shape: must be ShapeDtypeStruct(..., f32)."""
    if not (isinstance(call, ast.Call)
            and resolve(call.func, imports) == "jax.ShapeDtypeStruct"):
        diags.append(Diagnostic(
            CODE, path, call.lineno,
            "out_shape element is not a literal jax.ShapeDtypeStruct — the "
            "accumulator dtype cannot be statically verified as f32"))
        return
    dtype = keyword_arg(call, "dtype")
    if dtype is None and len(call.args) >= 2:
        dtype = call.args[1]
    if dtype is None or not _is_f32(dtype, imports):
        diags.append(Diagnostic(
            CODE, path, call.lineno,
            "pallas_call out_shape dtype must be jnp.float32 — the out_ref "
            "is the cross-step accumulator (downcast after the call "
            "instead)"))


def check(project: Project) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.matching("repro/kernels/fp_"):
        if f.tree is None:
            continue
        imports = ImportMap(f.tree)
        for node in ast.walk(f.tree):
            if not isinstance(node, ast.Call):
                continue
            name = resolve(node.func, imports)
            if name in _DOT_FUNCS:
                pet = keyword_arg(node, "preferred_element_type")
                if pet is None:
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f"{name.rsplit('.', 1)[1]} without "
                        f"preferred_element_type=jnp.float32 — the MXU "
                        f"accumulates in the input dtype (bf16) otherwise"))
                elif not _is_f32(pet, imports):
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        "preferred_element_type must be jnp.float32 in the "
                        "projection kernels"))
            elif name == _PALLAS_CALL:
                out_shape = keyword_arg(node, "out_shape")
                if out_shape is None:
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        "pallas_call without a literal out_shape — the "
                        "accumulator dtype cannot be statically verified"))
                elif isinstance(out_shape, (ast.Tuple, ast.List)):
                    for elt in out_shape.elts:
                        _check_struct(elt, imports, f.display, diags)
                else:
                    _check_struct(out_shape, imports, f.display, diags)
    return diags

"""RL002 — no bare ``assert`` in library code (``src/repro``)."""
from __future__ import annotations

import ast
from typing import List

from repro.lint.engine import Diagnostic, Project

CODE = "RL002"
NAME = "no-bare-assert"
EXPLAIN = """\
RL002 (no-bare-assert): library code must not validate with `assert`.

`assert` statements are stripped under `python -O`, so an assert-guarded
precondition silently stops being checked the moment someone runs the
serving stack optimized — and the AssertionError it raises when it does
fire carries no actionable message.  Library code under src/repro must
raise a typed exception instead:

    if geom.geom_type != "fan":
        raise ValueError(f"fp_fan needs a fan geometry, got "
                         f"{geom.geom_type!r}; dispatch through get_ops")

Tests and benchmarks are out of scope (pytest asserts are the point there).
Suppress a deliberate debug-only invariant with
`# repro-lint: disable=RL002` on the assert line.
"""


def _in_scope(display: str) -> bool:
    parts = display.split("/")
    return "repro" in parts and "tests" not in parts


def check(project: Project) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.files:
        if f.tree is None or not _in_scope(f.display):
            continue
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assert):
                diags.append(Diagnostic(
                    CODE, f.display, node.lineno,
                    "bare assert in library code (stripped under "
                    "python -O) — raise ValueError/TypeError with an "
                    "actionable message instead"))
    return diags

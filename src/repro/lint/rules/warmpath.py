"""RL005 — CTServer request path must not trigger compilation."""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from repro.lint.astutil import ImportMap, resolve
from repro.lint.engine import Diagnostic, Project

CODE = "RL005"
NAME = "warm-path"
EXPLAIN = """\
RL005 (warm-path): serving latency SLOs assume CTServer compiles only at
warm() time.  A jit/pallas/autotune call reachable from the request path
means the first production request of a new shape pays seconds of XLA
compilation inside its latency budget.

Contract: compile triggers (jax.jit, pl.pallas_call, tune.autotune,
power_iteration — which jits a power method internally) may appear only in
the memoized builder seam {warm, _executor, _solver_fn}.  The request-path
roots {submit, step, drain, take_responses, pending, _pick_bucket} and
every non-seam method/function they transitively call must be free of
them; the only way from a request to a compiler is through _executor's
memo dict, which warm() pre-populates.

Fix: move the trigger into _solver_fn/_executor and pre-trigger it from
warm().  Suppress (with a latency justification) via
`# repro-lint: disable=RL005`.
"""

_SEAM = {"warm", "_executor", "_solver_fn"}
_ROOTS = {"submit", "step", "drain", "take_responses", "pending",
          "_pick_bucket"}
_TRIGGER_RESOLVED = {"jax.jit", "jax.pmap", "jax.xla_computation"}
_TRIGGER_NAMES = {"pallas_call", "autotune", "power_iteration", "jit"}


def _in_scope(display: str) -> bool:
    return display.endswith("ct_serve.py")


def _trigger(node: ast.Call, imports: ImportMap) -> Optional[str]:
    name = resolve(node.func, imports)
    if name in _TRIGGER_RESOLVED:
        return name
    last = (name or "").rsplit(".", 1)[-1]
    if last in _TRIGGER_NAMES:
        return name
    return None


def _callees(fn: ast.FunctionDef, methods: Set[str],
             module_fns: Set[str]) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id == "self" \
                and node.func.attr in methods:
            out.add(node.func.attr)
        elif isinstance(node.func, ast.Name) and node.func.id in module_fns:
            out.add(node.func.id)
    return out


def check(project: Project) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.files:
        if f.tree is None or not _in_scope(f.display):
            continue
        imports = ImportMap(f.tree)
        server: Optional[ast.ClassDef] = None
        module_fns: Dict[str, ast.FunctionDef] = {}
        for node in ast.iter_child_nodes(f.tree):
            if isinstance(node, ast.ClassDef) and node.name == "CTServer":
                server = node
            elif isinstance(node, ast.FunctionDef):
                module_fns[node.name] = node
        if server is None:
            continue
        methods = {n.name: n for n in server.body
                   if isinstance(n, ast.FunctionDef)}
        lookup: Dict[str, ast.FunctionDef] = dict(module_fns)
        lookup.update(methods)

        reachable: Set[str] = set()
        todo = [r for r in _ROOTS if r in methods]
        while todo:
            name = todo.pop()
            if name in reachable or name in _SEAM:
                continue
            reachable.add(name)
            todo.extend(_callees(lookup[name], set(methods),
                                 set(module_fns)) - reachable)

        for name in sorted(reachable):
            for node in ast.walk(lookup[name]):
                if not isinstance(node, ast.Call):
                    continue
                trig = _trigger(node, imports)
                if trig:
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f"compile trigger {trig}() reachable from the "
                        f"CTServer request path via {name}() — move it "
                        f"behind the warm()/_executor()/_solver_fn() "
                        f"seam"))
    return diags

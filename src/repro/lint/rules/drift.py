"""RL003 — version-drift jax APIs only through ``repro.compat``."""
from __future__ import annotations

import ast
from typing import Dict, List

from repro.lint.astutil import ImportMap, resolve
from repro.lint.engine import Diagnostic, Project

CODE = "RL003"
NAME = "compat-firewall"
EXPLAIN = """\
RL003 (compat-firewall): APIs that moved between jax releases are shimmed
exactly once, in repro/compat.py, and every other module must go through
the shim:

    jax.experimental.shard_map.shard_map / jax.shard_map
        -> compat.shard_map          (kwarg renamed check_rep -> check_vma)
    jax.tree_util.tree_flatten_with_path / jax.tree.flatten_with_path
        -> compat.tree_flatten_with_path
    jax.tree_util.tree_map_with_path / jax.tree.map_with_path
        -> compat.tree_map_with_path
    compiled.cost_analysis()
        -> compat.cost_analysis_dict (list-of-dicts vs dict return drift)

A direct spelling works today and breaks on the next jax pin bump — the
jax-drift CI job catches it a release late, after the code has forked into
two spellings.  Routing through compat keeps one seam to patch.

Fix: `from repro import compat` and call the shim.  compat.py itself is
the only file allowed to touch the raw APIs.
"""

# resolved dotted name -> the compat shim to use instead
_FORBIDDEN: Dict[str, str] = {
    "jax.experimental.shard_map.shard_map": "compat.shard_map",
    "jax.shard_map": "compat.shard_map",
    "jax.tree_util.tree_flatten_with_path": "compat.tree_flatten_with_path",
    "jax.tree.flatten_with_path": "compat.tree_flatten_with_path",
    "jax.tree_util.tree_map_with_path": "compat.tree_map_with_path",
    "jax.tree.map_with_path": "compat.tree_map_with_path",
}


def _in_scope(display: str) -> bool:
    # Everything scanned except the shim itself (and this rule's own home).
    return not display.endswith("repro/compat.py")


def check(project: Project) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.files:
        if f.tree is None or not _in_scope(f.display):
            continue
        imports = ImportMap(f.tree)
        for node in ast.walk(f.tree):
            if isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    full = f"{node.module}.{a.name}"
                    if full in _FORBIDDEN:
                        diags.append(Diagnostic(
                            CODE, f.display, node.lineno,
                            f"import of {full} — use "
                            f"{_FORBIDDEN[full]} (from repro import "
                            f"compat)"))
            elif isinstance(node, ast.Attribute):
                name = resolve(node, imports)
                if name in _FORBIDDEN:
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f"direct {name} — use {_FORBIDDEN[name]}"))
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "cost_analysis":
                diags.append(Diagnostic(
                    CODE, f.display, node.lineno,
                    "direct .cost_analysis() call — use "
                    "compat.cost_analysis_dict(compiled) (return type "
                    "drifted from list-of-dicts to dict across jax "
                    "releases)"))
    return diags

"""RL006 — kernel registry completeness (introspection pass).

Unlike the AST rules this one imports the live package: a registry filled
at import time can only be checked by importing it.  It is skipped
silently when the scanned tree is not a repo checkout (no
``src/repro/kernels``), which is what lets the lint test fixtures run in a
tmp directory.
"""
from __future__ import annotations

import ast
import importlib
from typing import Dict, List, Tuple

from repro.lint.engine import Diagnostic, Project

CODE = "RL006"
NAME = "registry-complete"
EXPLAIN = """\
RL006 (registry-complete): every registered Pallas kernel arrives as a
*suite*, not a lone function.  For each (geom_type, model) entry in
repro.kernels.ops._KERNEL_TABLE the contract (PRs 2-5) is:

  * a matched BP — fp/bp are each other's VJP, so an entry without a bp
    silently breaks gradients;
  * a reference oracle in repro.kernels.ref (register_reference) — the
    correctness anchor every kernel test compares against;
  * a shape-class branch for the geom_type in kernels/tune.py
    (heuristic_config) — otherwise autotune falls back to defaults and
    the perf numbers are meaningless;
  * coverage in tests/test_adjoint.py (the BF16_GEOMS parametrization
    must name the geom_type) — the <A x, y> = <x, A^T y> dot test is the
    adjointness gate.

Fix: register the missing piece alongside the kernel.  Diagnostics anchor
at the register_kernel(...) call that created the incomplete entry.
"""


def _register_sites(project: Project) -> Dict[Tuple[str, str],
                                              Tuple[str, int]]:
    """(geom_type, model) -> (file, line) of its register_kernel call."""
    sites: Dict[Tuple[str, str], Tuple[str, int]] = {}
    for f in project.matching("repro/kernels/"):
        if f.tree is None:
            continue
        for node in ast.walk(f.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "register_kernel"
                    and len(node.args) >= 2
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[1], ast.Constant)):
                continue
            key = (str(node.args[0].value), str(node.args[1].value))
            sites[key] = (f.display, node.lineno)
    return sites


def _names_literal(path, literal: str) -> bool:
    if not path.exists():
        return False
    text = path.read_text(encoding="utf-8", errors="replace")
    return f'"{literal}"' in text or f"'{literal}'" in text


def check(project: Project) -> List[Diagnostic]:
    root = project.root
    kernels = root / "src" / "repro" / "kernels"
    # only a real checkout (ops + tune present) is introspectable — a
    # partial tree would produce anchors into files that don't exist
    if not ((kernels / "ops.py").is_file()
            and (kernels / "tune.py").is_file()):
        return []
    try:
        importlib.import_module("repro.kernels")
        ops = importlib.import_module("repro.kernels.ops")
        ref = importlib.import_module("repro.kernels.ref")
    except Exception as e:  # pragma: no cover - environment failure
        return [Diagnostic(CODE, "src/repro/kernels/ops.py", 1,
                           f"could not import repro.kernels to introspect "
                           f"the registry (run with PYTHONPATH=src): {e}")]

    sites = _register_sites(project)
    tune_path = root / "src" / "repro" / "kernels" / "tune.py"
    adjoint_path = root / "tests" / "test_adjoint.py"
    diags: List[Diagnostic] = []
    for key in sorted(ops._KERNEL_TABLE):
        geom_type, model = key
        entry = ops._KERNEL_TABLE[key]
        path, line = sites.get(key, ("src/repro/kernels/ops.py", 1))
        where = f"kernel entry ({geom_type!r}, {model!r})"
        if entry.bp is None:
            diags.append(Diagnostic(
                CODE, path, line,
                f"{where} has no matched BP — fp/bp must be registered as "
                f"a VJP pair"))
        if key not in ref._FP_TABLE:
            diags.append(Diagnostic(
                CODE, path, line,
                f"{where} has no reference oracle — add "
                f"ref.register_reference({geom_type!r}, {model!r}, ...)"))
        if not _names_literal(tune_path, geom_type):
            diags.append(Diagnostic(
                CODE, path, line,
                f"{where}: kernels/tune.py has no shape-class branch "
                f"naming {geom_type!r} — autotune would fall back to "
                f"defaults"))
        if not _names_literal(adjoint_path, geom_type):
            diags.append(Diagnostic(
                CODE, path, line,
                f"{where}: tests/test_adjoint.py does not parametrize "
                f"over {geom_type!r} — the adjoint dot-test must cover "
                f"every registered geometry"))
    return diags

"""RL004 — hash-stability of the spec/geometry identity paths."""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from repro.lint.astutil import ImportMap, keyword_arg, resolve
from repro.lint.engine import Diagnostic, Project

CODE = "RL004"
NAME = "stable-hashing"
EXPLAIN = """\
RL004 (stable-hashing): the serving cache keys must be content-stable.

ProjectorSpec.cache_key/bucket_key and CTGeometry.key/canonical_hash are
persisted (autotune disk cache, bucket routing) and compared across
processes — so every function on those paths must be a pure function of
*content*.  Inside the identity-path closure (the root functions plus
every same-module function they call) the rule flags:

  * id(...)            — process-specific object identity
  * hash(...)          — salted per-process (PYTHONHASHSEED)
  * repr(...) / f"{x!r}" — representation, not content (dataclass/ndarray
                           reprs change across library versions)
  * .items()/.keys()/.values() not wrapped in sorted(...) — dict order is
    insertion-dependent
  * json.dumps without sort_keys=True — unless the payload is a literal
    list/tuple, whose order is explicit and intentional

Fix: canonicalize first (float32 cast, sorted items, sha256 of raw bytes)
like geometry._canon_value does.  Suppress a genuinely order-explicit site
with `# repro-lint: disable=RL004` and a justifying comment.
"""

_ROOTS = {"key", "canonical_hash", "cache_key", "bucket_key", "_identity",
          "_canon_value"}
_VIEWS = {"items", "keys", "values"}


def _in_scope(display: str) -> bool:
    return display.endswith("core/spec.py") \
        or display.endswith("core/geometry.py")


def _functions(tree: ast.AST) -> Dict[str, ast.FunctionDef]:
    """All function/method defs keyed by bare name (methods shadow module
    functions of the same name only if defined later — fine here: the two
    scoped files keep names unique)."""
    out: Dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def _callees(fn: ast.FunctionDef, known: Set[str]) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Name) and node.func.id in known:
            out.add(node.func.id)
        elif isinstance(node.func, ast.Attribute) \
                and isinstance(node.func.value, ast.Name) \
                and node.func.value.id in ("self", "cls") \
                and node.func.attr in known:
            out.add(node.func.attr)
    return out


def _closure(funcs: Dict[str, ast.FunctionDef]) -> Set[str]:
    todo = [n for n in _ROOTS if n in funcs]
    seen: Set[str] = set()
    while todo:
        name = todo.pop()
        if name in seen:
            continue
        seen.add(name)
        todo.extend(_callees(funcs[name], set(funcs)) - seen)
    return seen


def _sorted_args(fn: ast.FunctionDef) -> Set[int]:
    """ids of call nodes that appear directly as an argument of sorted()."""
    out: Set[int] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "sorted":
            for a in node.args:
                out.add(id(a))
    return out


def check(project: Project) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.files:
        if f.tree is None or not _in_scope(f.display):
            continue
        imports = ImportMap(f.tree)
        funcs = _functions(f.tree)
        for name in sorted(_closure(funcs)):
            fn = funcs[name]
            ok_sorted = _sorted_args(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in ("id", "hash", "repr"):
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f"{node.func.id}() in identity path {name}() is "
                        f"not content-stable across processes"))
                elif isinstance(node, ast.FormattedValue) \
                        and node.conversion == ord("r"):
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f"!r conversion in identity path {name}() — repr "
                        f"is representation, not content"))
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _VIEWS \
                        and not node.args and not node.keywords \
                        and id(node) not in ok_sorted:
                    diags.append(Diagnostic(
                        CODE, f.display, node.lineno,
                        f".{node.func.attr}() in identity path {name}() "
                        f"must be wrapped in sorted(...) — dict order is "
                        f"insertion-dependent"))
                elif isinstance(node, ast.Call) \
                        and resolve(node.func, imports) == "json.dumps":
                    sk = keyword_arg(node, "sort_keys")
                    stable = (isinstance(sk, ast.Constant)
                              and sk.value is True)
                    literal_seq = bool(node.args) and isinstance(
                        node.args[0], (ast.List, ast.Tuple))
                    if not stable and not literal_seq:
                        diags.append(Diagnostic(
                            CODE, f.display, node.lineno,
                            f"json.dumps in identity path {name}() needs "
                            f"sort_keys=True (or a literal list payload "
                            f"with explicit order)"))
    return diags

"""CLI: ``python -m repro.lint [paths...] [--explain RL00x] [--select ...]``.

Exit codes: 0 clean, 1 violations found, 2 usage error.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from repro.lint.engine import collect, run_rules
from repro.lint.rules import ALL_RULES, by_code


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="repro-lint: AST-level invariant checker for the "
                    "kernel-suite contracts (RL001-RL007)")
    ap.add_argument("paths", nargs="*",
                    help="files or directories to lint "
                         "(typically: src tests benchmarks)")
    ap.add_argument("--explain", metavar="CODE",
                    help="print the contract behind a rule code and exit")
    ap.add_argument("--select", metavar="CODES",
                    help="comma-separated rule codes to run "
                         "(default: all)")
    ap.add_argument("--root", default=".",
                    help="repo root for the project-level rules "
                         "(registry/bench-rows); default: cwd")
    args = ap.parse_args(argv)

    if args.explain:
        rule = by_code(args.explain)
        if rule is None:
            codes = ", ".join(r.CODE for r in ALL_RULES)
            print(f"unknown rule {args.explain!r}; known: {codes}",
                  file=sys.stderr)
            return 2
        print(rule.EXPLAIN, end="")
        return 0

    if not args.paths:
        ap.error("no paths given (try: python -m repro.lint src tests "
                 "benchmarks)")

    select = None
    if args.select:
        select = {c.strip().upper() for c in args.select.split(",")
                  if c.strip()}
        unknown = select - {r.CODE for r in ALL_RULES}
        if unknown:
            ap.error(f"unknown rule code(s): {', '.join(sorted(unknown))}")

    root = pathlib.Path(args.root).resolve()
    src = root / "src"
    if src.is_dir() and str(src) not in sys.path:
        sys.path.insert(0, str(src))
    try:
        project = collect(args.paths, root)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 2

    diags = run_rules(project, ALL_RULES, select)
    for d in diags:
        print(d.format())
    if diags:
        codes = sorted({d.code for d in diags})
        print(f"repro-lint: {len(diags)} violation(s) "
              f"[{', '.join(codes)}] in {len(project.files)} file(s) — "
              f"`python -m repro.lint --explain <code>` for the contract")
        return 1
    print(f"repro-lint: OK ({len(project.files)} files, "
          f"{len(ALL_RULES) if not select else len(select)} rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

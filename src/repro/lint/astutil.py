"""Shared AST helpers: import-alias resolution for dotted names.

The rules need to answer "is this call ``jax.lax.dot_general``?" robustly
against the repo's import idioms (``import jax.numpy as jnp``,
``from jax.experimental import pallas as pl``, ``from repro import
compat``).  ``ImportMap`` records every alias a module introduces;
``resolve`` expands an ``ast.Name``/``ast.Attribute`` chain through it to a
canonical dotted path.
"""
from __future__ import annotations

import ast
from typing import Dict, Optional


class ImportMap:
    """Alias -> canonical dotted prefix, built from a module's imports."""

    def __init__(self, tree: ast.AST):
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    # "import jax.numpy as jnp" -> jnp: jax.numpy
                    # "import jax.numpy"        -> jax: jax (root binding)
                    if a.asname:
                        self.aliases[a.asname] = a.name
                    else:
                        root = a.name.split(".")[0]
                        self.aliases.setdefault(root, root)
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    if a.name == "*":
                        continue
                    bound = a.asname or a.name
                    self.aliases[bound] = f"{node.module}.{a.name}"


def literal_chain(node: ast.AST) -> Optional[str]:
    """The attribute chain exactly as written ('pl.pallas_call'), or None
    for anything that is not a pure Name/Attribute chain."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


def resolve(node: ast.AST, imports: ImportMap) -> Optional[str]:
    """Alias-expanded dotted name ('jax.experimental.pallas.pallas_call'),
    or the literal chain when the root is not an import alias (locals)."""
    chain = literal_chain(node)
    if chain is None:
        return None
    root, _, rest = chain.partition(".")
    base = imports.aliases.get(root)
    if base is None:
        return chain
    return f"{base}.{rest}" if rest else base


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None

"""Lint engine: file collection, pragma suppression, rule runner.

Design notes:

* Files are parsed once into ``SourceFile`` objects shared by every rule;
  a syntax error becomes an ``RL000`` diagnostic instead of a crash (a file
  the linter cannot parse is a file CI cannot trust).
* Suppression is tokenizer-based, not regex-over-lines, so a pragma inside
  a string literal does not suppress anything.  A pragma applies to the
  physical line it sits on — put it on the line the diagnostic points at::

      risky()   # repro-lint: disable=RL004  <why this one is safe>

* Rules are plain modules exposing ``CODE``, ``NAME``, ``EXPLAIN`` and
  ``check(project) -> list[Diagnostic]``; per-file scoping lives inside
  each rule so the engine stays policy-free.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Set

PRAGMA = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    code: str
    path: str        # display path (repo-relative when run from the root)
    line: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def _suppressions(text: str) -> Dict[int, Set[str]]:
    """{physical line -> set of suppressed codes (lower-cased; 'all' ok)}."""
    out: Dict[int, Set[str]] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(text).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = PRAGMA.search(tok.string)
            if not m:
                continue
            codes = {c.strip().lower() for c in m.group(1).split(",")
                     if c.strip()}
            out.setdefault(tok.start[0], set()).update(codes)
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass  # the parse error is reported separately as RL000
    return out


class SourceFile:
    """One parsed python file plus its pragma map."""

    def __init__(self, path: pathlib.Path, display: str):
        self.path = path
        self.display = display.replace("\\", "/")
        self.text = path.read_text(encoding="utf-8", errors="replace")
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(self.text, filename=str(path))
        except SyntaxError as e:
            self.parse_error = f"syntax error: {e.msg} (line {e.lineno})"
        self.suppressed = _suppressions(self.text)

    def is_suppressed(self, code: str, line: int) -> bool:
        codes = self.suppressed.get(line, ())
        return code.lower() in codes or "all" in codes


class Project:
    """Everything a rule may look at: the parsed files plus the repo root
    (project-level rules find benchmarks/ci.yml/the kernel registry under
    the root and silently skip when it isn't a repo checkout — that is what
    lets the test fixtures run file-scoped rules in a tmp dir)."""

    def __init__(self, files: Sequence[SourceFile], root: pathlib.Path):
        self.files = list(files)
        self.root = root

    def by_suffix(self, *suffixes: str) -> List[SourceFile]:
        return [f for f in self.files
                if any(f.display.endswith(s) for s in suffixes)]

    def matching(self, substring: str) -> List[SourceFile]:
        return [f for f in self.files if substring in f.display]


def _iter_py(path: pathlib.Path) -> Iterable[pathlib.Path]:
    if path.is_file():
        if path.suffix == ".py":
            yield path
        return
    for p in sorted(path.rglob("*.py")):
        parts = p.relative_to(path).parts
        if any(part == "__pycache__" or part.startswith(".")
               for part in parts):
            continue
        yield p


def collect(paths: Sequence[str], root: pathlib.Path) -> Project:
    files: List[SourceFile] = []
    seen: Set[pathlib.Path] = set()
    for raw in paths:
        base = pathlib.Path(raw)
        if not base.is_absolute():
            base = root / base
        if not base.exists():
            raise FileNotFoundError(f"lint target does not exist: {raw}")
        for p in _iter_py(base):
            rp = p.resolve()
            if rp in seen:
                continue
            seen.add(rp)
            try:
                display = str(rp.relative_to(root.resolve()))
            except ValueError:
                display = str(p)
            files.append(SourceFile(p, display))
    return Project(files, root)


def run_rules(project: Project, rules: Sequence,
              select: Optional[Set[str]] = None) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for f in project.files:
        if f.parse_error:
            diags.append(Diagnostic("RL000", f.display, 1, f.parse_error))
    by_display = {f.display: f for f in project.files}
    for rule in rules:
        if select and rule.CODE not in select:
            continue
        for d in rule.check(project):
            sf = by_display.get(d.path)
            if sf is not None and sf.is_suppressed(d.code, d.line):
                continue
            diags.append(d)
    return sorted(diags, key=lambda d: (d.path, d.line, d.code))

"""CT-Net-style sinogram completion network (Anirudh et al. 2018, simplified).

Operates in the projection domain: takes the masked sinogram (missing views
zeroed) plus the mask channel and predicts the completed sinogram.  Combined
with the image-domain U-Net this reproduces the paper's §4 hybrid
(CT-Net + U-Net) limited-angle model; both halves train end-to-end because
the FBP/projector bridge between the domains is differentiable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import modules as m


def ctnet_init(key, base: int = 32, depth: int = 4, dtype=jnp.float32):
    keys = iter(jax.random.split(key, 2 * depth + 2))
    layers = []
    ch = 2  # sinogram + mask
    for i in range(depth):
        cl = base * (2 ** min(i, 2))
        layers.append({
            "c": m.conv2d_init(next(keys), ch, cl, dtype=dtype),
            "n": m.group_norm_init(cl, dtype),
        })
        ch = cl
    return {"layers": layers, "out": m.conv2d_init(next(keys), ch, 1, k=1,
                                                   dtype=dtype)}


def ctnet_apply(p, sino, mask):
    """sino/mask: (B, n_angles, n_cols) -> completed sinogram (B, na, nu).
    Measured views are passed through; only missing views are predicted."""
    x = jnp.stack([sino, mask], axis=-1)                     # (B, na, nu, 2)
    h = x
    for lyr in p["layers"]:
        h = m.silu(m.group_norm(lyr["n"], m.conv2d(lyr["c"], h)))
    pred = m.conv2d(p["out"], h)[..., 0]
    return mask * sino + (1.0 - mask) * pred

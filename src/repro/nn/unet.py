"""U-Net artifact-removal network (Han & Ye 2018 style) — the image-domain
half of the paper's limited-angle experiment.  Input: ill-posed FBP slice;
output: residual-corrected slice."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn import modules as m


def unet_init(key, base: int = 32, levels: int = 3, in_ch: int = 1,
              out_ch: int = 1, dtype=jnp.float32):
    keys = iter(jax.random.split(key, 64))
    p = {"levels": []}
    ch = in_ch
    chans = [base * (2 ** l) for l in range(levels)]
    for cl in chans:
        p["levels"].append({
            "c1": m.conv2d_init(next(keys), ch, cl, dtype=dtype),
            "n1": m.group_norm_init(cl, dtype),
            "c2": m.conv2d_init(next(keys), cl, cl, dtype=dtype),
            "n2": m.group_norm_init(cl, dtype),
        })
        ch = cl
    p["mid"] = {
        "c1": m.conv2d_init(next(keys), ch, ch * 2, dtype=dtype),
        "n1": m.group_norm_init(ch * 2, dtype),
        "c2": m.conv2d_init(next(keys), ch * 2, ch * 2, dtype=dtype),
        "n2": m.group_norm_init(ch * 2, dtype),
    }
    ch = ch * 2
    p["ups"] = []
    for cl in reversed(chans):
        p["ups"].append({
            "up": m.conv2d_init(next(keys), ch, cl, k=3, dtype=dtype),
            "c1": m.conv2d_init(next(keys), cl * 2, cl, dtype=dtype),
            "n1": m.group_norm_init(cl, dtype),
            "c2": m.conv2d_init(next(keys), cl, cl, dtype=dtype),
            "n2": m.group_norm_init(cl, dtype),
        })
        ch = cl
    p["out"] = m.conv2d_init(next(keys), ch, out_ch, k=1, dtype=dtype)
    # zero-init the output head: the net is the identity (residual) at init,
    # which keeps training stable when image values are in physical 1/mm
    # units (O(0.01)) while GroupNorm makes hidden activations O(1).
    p["out"]["w"] = jnp.zeros_like(p["out"]["w"])
    return p


def _block(p, x):
    x = m.silu(m.group_norm(p["n1"], m.conv2d(p["c1"], x)))
    x = m.silu(m.group_norm(p["n2"], m.conv2d(p["c2"], x)))
    return x


def unet_apply(p, x):
    """x: (B, H, W, C) -> (B, H, W, out_ch); residual connection on channel 0."""
    skips = []
    h = x
    for lvl in p["levels"]:
        h = _block(lvl, h)
        skips.append(h)
        h = m.avg_pool(h)
    h = _block(p["mid"], h)
    for up, skip in zip(p["ups"], reversed(skips)):
        h = m.upsample_nearest(h)
        h = m.conv2d(up["up"], h)
        h = jnp.concatenate([h, skip], axis=-1)
        h = _block(up, h)
    out = m.conv2d(p["out"], h)
    return out + x[..., :out.shape[-1]]

"""Minimal functional NN layers (from scratch — no flax/haiku in this stack).

Parameters are plain nested dicts of jnp arrays; every layer is an
(init, apply) pair of pure functions.  NHWC layout throughout.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def conv2d_init(key, in_ch: int, out_ch: int, k: int = 3, dtype=jnp.float32):
    fan_in = in_ch * k * k
    w = jax.random.normal(key, (k, k, in_ch, out_ch), dtype) * math.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((out_ch,), dtype)}


def conv2d(params, x, stride: int = 1, padding: str = "SAME"):
    y = jax.lax.conv_general_dilated(
        x, params["w"], window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["b"]


def conv2d_transpose(params, x, stride: int = 2):
    y = jax.lax.conv_transpose(
        x, params["w"], strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + params["b"]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale=None):
    s = scale if scale is not None else math.sqrt(1.0 / d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * s,
            "b": jnp.zeros((d_out,), dtype)}


def dense(params, x):
    return x @ params["w"] + params["b"]


def group_norm_init(ch: int, dtype=jnp.float32):
    return {"scale": jnp.ones((ch,), dtype), "bias": jnp.zeros((ch,), dtype)}


def group_norm(params, x, groups: int = 8, eps: float = 1e-5):
    n, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * params["scale"] + params["bias"]


def silu(x):
    return x * jax.nn.sigmoid(x)


def avg_pool(x, k: int = 2):
    return jax.lax.reduce_window(x, 0.0, jax.lax.add, (1, k, k, 1),
                                 (1, k, k, 1), "VALID") / (k * k)


def upsample_nearest(x, k: int = 2):
    n, h, w, c = x.shape
    x = jnp.broadcast_to(x[:, :, None, :, None, :], (n, h, k, w, k, c))
    return x.reshape(n, h * k, w * k, c)


def count_params(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))

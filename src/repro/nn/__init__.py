from repro.nn import modules, unet, ctnet  # noqa: F401

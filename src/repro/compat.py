"""Version-compatibility shims for drift-prone jax APIs.

The repo pins jax in ``requirements-test.txt`` but must keep working as the
pin moves (the ``jax-drift`` CI leg runs tier-1 against the latest release).
Every API that jax has renamed/moved recently — and that previously broke a
whole test suite with an ``AttributeError`` at call time — is funneled
through this module so the next rename is a one-line fix here instead of a
sweep across the tree.

Covered drift:

* ``shard_map`` — promoted from ``jax.experimental.shard_map`` to
  ``jax.shard_map`` (and its replication-check kwarg renamed
  ``check_rep`` -> ``check_vma``) in jax 0.6/0.7.
* ``tree_flatten_with_path`` / ``tree_map_with_path`` — ``jax.tree.*``
  only grew the ``*_with_path`` variants after 0.4.37; the
  ``jax.tree_util`` spellings exist on every supported version.
* ``Compiled.cost_analysis()`` — returned a one-element *list* of dicts
  up to jax 0.4.x and a plain dict from 0.5; ``cost_analysis_dict``
  normalizes both to a dict.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.core

__all__ = [
    "shard_map",
    "axis_size",
    "tree_flatten_with_path",
    "tree_map_with_path",
    "cost_analysis_dict",
]


# --------------------------------------------------------------------------- #
# shard_map: jax.experimental.shard_map (<= 0.4/0.5, kwarg check_rep) vs
# jax.shard_map (>= 0.6, kwarg check_vma).
# --------------------------------------------------------------------------- #
def shard_map(f: Callable, mesh, in_specs, out_specs,
              check_vma: bool = True) -> Callable:
    """Dispatch to whichever ``shard_map`` this jax ships.

    ``check_vma`` follows the new-jax spelling; it maps onto ``check_rep``
    on versions that predate the rename (the semantics are identical for
    our usage: disable the replication/varying-mesh-axes check).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def axis_size(name) -> int:
    """Static size of a named mesh axis inside ``shard_map``.

    ``jax.lax.axis_size`` only exists on new jax; older versions expose the
    same static value through ``jax.core.axis_frame``."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return int(jax.core.axis_frame(name))


# --------------------------------------------------------------------------- #
# tree path helpers: jax.tree_util works everywhere; jax.tree.* only on
# new jax.
# --------------------------------------------------------------------------- #
tree_flatten_with_path = jax.tree_util.tree_flatten_with_path
tree_map_with_path = jax.tree_util.tree_map_with_path


# --------------------------------------------------------------------------- #
# Compiled.cost_analysis(): list-of-dicts (old) vs dict (new).
# --------------------------------------------------------------------------- #
def cost_analysis_dict(compiled: Any) -> Dict[str, Any]:
    """``compiled.cost_analysis()`` normalized to a flat dict (possibly
    empty — some backends return None)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, dict):
        return cost
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost and isinstance(cost[0], dict) else {}
    return {}

"""Exponential moving average of parameters (eval-time weights).

The trained-model path evaluates (and serves) the EMA of the online
parameters, not the last SGD iterate — the standard trick behind the
reported numbers of every modern recon network (Genzel et al.'s near-exact
recovery harness, the RSNA diffusion-recon pipelines in the related repos).

Follows the repo's optimizer convention: a NamedTuple state living in the
same pytree structure as the parameters, pure ``init`` / ``update``
functions, jit-safe throughout::

    ema = ema_init(params)
    ema = ema_update(ema, params, decay=0.999)      # once per train step
    metrics = evaluate(ema_params(ema), ...)        # eval on the average

Decay warmup: a fixed 0.999 decay makes the average lag hundreds of steps
behind a freshly initialized network, so early evaluations see near-random
weights.  The effective decay ramps as

    decay_t = min(decay, (1 + t) / (warmup + t))

which starts near a plain running mean (decay_1 ~ 2/warmup) and approaches
the target asymptotically — the Polyak-averaging warmup used by the
diffusion-model EMA implementations.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EmaState(NamedTuple):
    step: jnp.ndarray     # int32 scalar — number of updates applied
    params: Any           # the averaged pytree (same structure as params)


def ema_init(params) -> EmaState:
    """Start the average at the current parameters (not zeros: a zero start
    would need bias correction everywhere the average is read)."""
    return EmaState(step=jnp.zeros((), jnp.int32),
                    params=jax.tree.map(jnp.asarray, params))


def ema_decay_schedule(step, decay: float, warmup: int):
    """Effective decay at update ``step`` (1-based), warmed up from ~0."""
    t = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    return jnp.minimum(jnp.asarray(decay, jnp.float32),
                       (1.0 + t) / (float(warmup) + t))


def ema_update(state: EmaState, params, decay: float = 0.999,
               warmup: int = 10) -> EmaState:
    """One EMA step: ``avg <- d * avg + (1 - d) * params`` with warmed-up
    ``d`` (see module docstring).  Pure/jittable; call it after every
    optimizer update."""
    if not 0.0 <= decay < 1.0:
        raise ValueError(f"decay must be in [0, 1), got {decay}")
    if warmup < 1:
        raise ValueError(f"warmup must be >= 1, got {warmup}")
    step = state.step + 1
    d = ema_decay_schedule(step, decay, warmup)
    avg = jax.tree.map(
        lambda a, p: (d * a.astype(jnp.float32)
                      + (1.0 - d) * p.astype(jnp.float32)).astype(a.dtype),
        state.params, params)
    return EmaState(step=step, params=avg)


def ema_params(state: EmaState):
    """The averaged parameters (what evaluation should consume)."""
    return state.params

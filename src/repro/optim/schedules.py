"""Learning-rate schedules (pure functions of the integer step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def f(step):
        frac = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        return jnp.asarray(lr * frac, jnp.float32)
    return f


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def f(step):
        t = jnp.minimum(step / max(decay_steps, 1), 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * ((1 - alpha) * cos + alpha), jnp.float32)
    return f


def warmup_cosine(lr: float, warmup_steps: int, total_steps: int,
                  alpha: float = 0.1):
    def f(step):
        w = jnp.minimum(step / max(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.asarray(lr * w * ((1 - alpha) * cos + alpha), jnp.float32)
    return f

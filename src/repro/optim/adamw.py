"""From-scratch optimizers (no optax in this stack, by design).

Optimizers follow the (init, update) pair convention::

    opt = adamw(schedule=warmup_cosine(3e-4, 100, 1000))
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Optimizer state lives in the same pytree structure (and, under pjit, the same
shardings) as the parameters — this is what makes ZeRO-style sharded
optimizer state fall out for free in ``repro.launch``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable      # (grads, state, params) -> (updates, state)


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict


def adamw(schedule: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          state_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(grads, state: AdamWState, params):
        step = state.step + 1
        lr = schedule(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(state_dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / bc1
            vhat = v / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                       + weight_decay * p.astype(state_dtype))
            return u.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return updates, AdamWState(step=step, mu=mu, nu=nu)

    return Optimizer(init, update)


class SGDState(NamedTuple):
    step: jnp.ndarray
    mom: Optional[dict]


def sgd(schedule: Callable, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = (jax.tree.map(jnp.zeros_like, params) if momentum else None)
        return SGDState(step=jnp.zeros((), jnp.int32), mom=mom)

    def update(grads, state: SGDState, params):
        step = state.step + 1
        lr = schedule(step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g, state.mom, grads)
            updates = jax.tree.map(lambda m: -lr * m, mom)
            return updates, SGDState(step, mom)
        updates = jax.tree.map(lambda g: -lr * g, grads)
        return updates, SGDState(step, None)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn

from repro.optim.adamw import adamw, sgd, clip_by_global_norm, apply_updates
from repro.optim.ema import (EmaState, ema_decay_schedule, ema_init,
                             ema_params, ema_update)
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)

__all__ = ["adamw", "sgd", "clip_by_global_norm", "apply_updates",
           "constant", "cosine_decay", "linear_warmup", "warmup_cosine",
           "EmaState", "ema_init", "ema_update", "ema_params",
           "ema_decay_schedule"]

from repro.optim.adamw import adamw, sgd, clip_by_global_norm, apply_updates
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)

__all__ = ["adamw", "sgd", "clip_by_global_norm", "apply_updates",
           "constant", "cosine_decay", "linear_warmup", "warmup_cosine"]

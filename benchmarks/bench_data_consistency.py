"""Paper Fig. 3: limited-angle inference + data-consistency refinement.
Trains the small U-Net for a short schedule, then reports PSNR/SSIM of the
network prediction vs the refined image on held-out phantoms (the paper
reports 35.486/0.905 -> 36.350/0.911 on luggage data; we reproduce the
*improvement* on synthetic phantoms)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Projector, VolumeGeometry, parallel_beam
from repro.data.metrics import psnr, ssim
from repro.data.pipeline import CTDataPipeline
from repro.nn.unet import unet_apply, unet_init
from repro.optim import adamw, apply_updates, constant
from repro.recon import complete_and_refine


def run(csv_rows: list, n=48, steps=40, n_test=4):
    vol = VolumeGeometry(n, n, 1)
    geom = parallel_beam(72, 1, int(1.5 * n), vol)
    proj = Projector(geom, "sf")
    pipe = CTDataPipeline(geom, batch_size=4, seed=0, available_deg=60.0)
    params = unet_init(jax.random.PRNGKey(0), base=8, levels=2)
    opt = adamw(constant(2e-3))
    state = opt.init(params)

    @jax.jit
    def step(p, s, x_in, gt, sino, mask):
        def loss(p):
            pred = unet_apply(p, x_in[..., None])[..., 0]
            dc = jnp.mean(jnp.square((proj(pred[..., None]) - sino) * mask))
            return jnp.mean((pred - gt) ** 2) + 0.1 * dc
        l, g = jax.value_and_grad(loss)(p)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    t0 = time.perf_counter()
    for i in range(steps):
        imgs, masks = pipe.batch(i)
        gt = jnp.asarray(imgs)
        sino = proj(gt[..., None])
        mvec = jnp.asarray(masks)[:, :, None, None]
        x_in = proj.fbp(sino * mvec)[..., 0]
        params, state, _ = step(params, state, x_in, gt, sino, mvec)
    t_train = time.perf_counter() - t0

    p_net, p_ref, s_net, s_ref = [], [], [], []
    for k in range(n_test):
        img, mask = pipe.sample(10_000 + k, 0)
        gt = jnp.asarray(img)
        sino = proj(gt[..., None])
        mvec = jnp.asarray(mask)[:, None, None]
        x_in = proj.fbp(sino * mvec)[..., 0]
        pred = unet_apply(params, x_in[None, ..., None])[0, ..., 0]
        xr, _ = complete_and_refine(proj, pred[..., None], sino, mvec,
                                    n_iters=20, beta=0.05)
        peak = float(gt.max())
        p_net.append(psnr(pred, gt, peak))
        p_ref.append(psnr(np.asarray(xr)[..., 0], gt, peak))
        s_net.append(ssim(pred, gt, peak))
        s_ref.append(ssim(np.asarray(xr)[..., 0], gt, peak))
    csv_rows.append(("fig3/train", t_train / steps * 1e6,
                     f"steps={steps}"))
    csv_rows.append(("fig3/psnr_net_vs_refined", 0.0,
                     f"{np.mean(p_net):.3f}->{np.mean(p_ref):.3f}dB"))
    csv_rows.append(("fig3/ssim_net_vs_refined", 0.0,
                     f"{np.mean(s_net):.4f}->{np.mean(s_ref):.4f}"))

"""Paper Fig. 3 at CI scale: projector-in-the-loop training + DC refinement
quality, per hard geometry.

Runs the tiny :func:`repro.launch.ct_train.smoke_config` schedule for each
of the three hard geometries (limited-angle parallel, sparse-view fan,
helical modular), then reports held-out reconstruction quality through the
full paper-§4 inference pipeline.  The ``quality/...`` rows feed the
floor-style regression gate in ``check_regression.py`` — reconstruction
quality gets the same CI machinery as kernel latency:

    quality/<geom>/psnr_net       raw network prediction PSNR (dB, EMA params)
    quality/<geom>/psnr_refined   after CG data-consistency refinement (dB)
    quality/<geom>/ssim_refined   SSIM of the refined image
    quality/<geom>/dc_residual    relative projection residual of the
                                  refined image (lower is better)

(The paper reports 35.486/0.905 -> 36.350/0.911 on luggage data; we gate the
*improvement* and its stability on synthetic phantoms.)  The ``fig3/...``
latency rows stay informational (training time is machine-bound; quality is
not)."""
from __future__ import annotations

import time

from repro.launch.ct_train import GEOMETRIES, CTTrainer, smoke_config


def run(csv_rows: list, steps: int = 40, n_test: int = 4):
    for geometry in GEOMETRIES:
        cfg = smoke_config(geometry, steps=steps)
        trainer = CTTrainer(cfg)
        t0 = time.perf_counter()
        trainer.fit(log_every=0)
        t_train = time.perf_counter() - t0
        m = trainer.evaluate(n_test=n_test)
        csv_rows.append((f"fig3/{geometry}/train_step",
                         t_train / cfg.steps * 1e6, f"steps={cfg.steps}"))
        csv_rows.append((f"quality/{geometry}/psnr_net",
                         m["psnr_net"], "quality-db"))
        csv_rows.append((f"quality/{geometry}/psnr_refined",
                         m["psnr_refined"], "quality-db"))
        csv_rows.append((f"quality/{geometry}/ssim_refined",
                         m["ssim_refined"], "quality-ssim"))
        csv_rows.append((f"quality/{geometry}/dc_residual",
                         m["dc_refined"], "quality-residual"))

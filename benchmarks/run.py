"""Benchmark harness — one module per paper table/figure + framework extras.
Prints ``name,us_per_call,derived`` CSV (harness contract).

    PYTHONPATH=src python -m benchmarks.run [--only substring]
"""
from __future__ import annotations

import argparse
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()

    from benchmarks import (bench_archs, bench_data_consistency,
                            bench_distributed, bench_kernels,
                            bench_projectors, bench_recon, bench_serve)
    suites = {
        "table1_projectors": bench_projectors.run,
        "recon_pipeline": bench_recon.run,
        "fig3_data_consistency": bench_data_consistency.run,
        "kernels": bench_kernels.run,
        "archs": bench_archs.run,
        "serve": bench_serve.run,
        "distributed": bench_distributed.run,
    }
    print("name,us_per_call,derived", flush=True)
    for name, fn in suites.items():
        if args.only and args.only not in name:
            continue
        rows: list = []
        try:
            fn(rows)
        except Exception:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            rows.append((f"{name}/ERROR", -1.0, "failed"))
        for rname, us, derived in rows:
            # .6g, not .1f: quality rows carry metric values (SSIM ~0.9,
            # residuals ~0.01) that a fixed single decimal would destroy.
            print(f"{rname},{us:.6g},{derived}", flush=True)
        # drop compiled programs between suites (CPU-RAM hygiene)
        import jax
        jax.clear_caches()


if __name__ == "__main__":
    main()

"""Per-architecture step benchmark at reduced (CPU-runnable) configs:
train-step and decode-step wall time for every assigned arch."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import model as MD
from repro.optim import adamw, constant


def run(csv_rows: list):
    for arch in configs.ARCHS:
        cfg = dataclasses.replace(configs.get_smoke(arch), grad_accum=1)
        params = MD.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw(constant(1e-3))
        state = opt.init(params)
        B, S = 4, 128
        toks = (jnp.zeros((B, cfg.n_codebooks, S), jnp.int32)
                if cfg.n_codebooks > 1 else jnp.zeros((B, S), jnp.int32))
        batch = {"tokens": toks}
        if cfg.vision_tokens:
            batch["vision_embeds"] = jnp.zeros((B, cfg.vision_tokens,
                                                cfg.d_model))
            if cfg.rope == "mrope":
                St = S + cfg.vision_tokens
                batch["positions"] = jnp.broadcast_to(
                    jnp.arange(St)[None, None], (3, B, St))
        step = jax.jit(make_train_step(cfg, opt))
        params, state, _ = step(params, state, batch)   # compile
        t0 = time.perf_counter()
        params, state, m = step(params, state, batch)
        jax.block_until_ready(m["loss"])
        t_train = time.perf_counter() - t0
        serve = jax.jit(make_serve_step(cfg))
        cache = MD.init_cache(cfg, B, 64)
        tok = (jnp.zeros((B, cfg.n_codebooks), jnp.int32)
               if cfg.n_codebooks > 1 else jnp.zeros((B,), jnp.int32))
        nxt, lg, cache = serve(params, cache, tok, jnp.asarray(0, jnp.int32))
        t0 = time.perf_counter()
        nxt, lg, cache = serve(params, cache, nxt, jnp.asarray(1, jnp.int32))
        jax.block_until_ready(lg)
        t_dec = time.perf_counter() - t0
        csv_rows.append((f"arch/{arch}/train_step", t_train * 1e6,
                         f"smoke B{B}xS{S}"))
        csv_rows.append((f"arch/{arch}/decode_step", t_dec * 1e6, "smoke"))

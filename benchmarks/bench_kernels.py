"""Kernel microbenchmark: Pallas (interpret on CPU) vs pure-jnp oracle at
matched shapes, plus the jnp backend at production-ish 2D sizes.  On real
TPU the pallas path is the production backend; interpret-mode timing is a
correctness artifact, not a perf number — flagged in `derived`."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import VolumeGeometry, parallel_beam
from repro.kernels import ref
from repro.kernels.fp_par import fp_parallel_sf_pallas


def _t(fn, *a, reps=2):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(csv_rows: list):
    vol = VolumeGeometry(64, 64, 8)
    g = parallel_beam(24, 8, 96, vol)
    f = jnp.asarray(np.random.default_rng(0).normal(
        size=vol.shape).astype(np.float32))
    t_ref = _t(jax.jit(lambda x: ref.forward(x, g, "sf")), f)
    csv_rows.append(("kernel/fp_par_sf/jnp_oracle", t_ref * 1e6,
                     "cpu-jit"))
    t_pal = _t(lambda x: fp_parallel_sf_pallas(x, g), f, reps=1)
    csv_rows.append(("kernel/fp_par_sf/pallas", t_pal * 1e6,
                     "interpret-mode(correctness-only)"))
    # 2D production-ish slice (the paper's 512^2 limited-angle setting)
    vol2 = VolumeGeometry(256, 256, 1)
    g2 = parallel_beam(180, 1, 384, vol2)
    f2 = jnp.asarray(np.random.default_rng(1).normal(
        size=vol2.shape).astype(np.float32))
    t2 = _t(jax.jit(lambda x: ref.forward(x, g2, "sf")), f2)
    csv_rows.append(("kernel/fp_256x256x180", t2 * 1e6, "cpu-jit"))

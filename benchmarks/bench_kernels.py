"""Kernel microbenchmark: Pallas (interpret on CPU) vs pure-jnp oracle at
matched shapes, plus the jnp backend at production-ish 2D sizes.  On real
TPU the pallas path is the production backend; interpret-mode timing is a
correctness artifact, not a perf number — flagged in `derived`.

From the lane-packing PR onward this also records, on every runner:

* backprojection and forward+VJP (gradient) timings,
* the paper's flagship batched 2D training shape (nz=1, n_rows=1, batch>=8)
  on BOTH the seed per-sample vmap path and the lane-packed batched path,
  sweeping view-block configs — so the lane-packing win (up to 128x lane
  occupancy) is tracked in BENCH_*.json across PRs.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (VolumeGeometry, cone_beam, fan_beam, helical_beam,
                        parallel_beam)
from repro.kernels import ref
from repro.kernels.fp_cone import (bp_cone_packed, bp_cone_sf_pallas,
                                   cone_packed_row_shift, fp_cone_packed,
                                   fp_cone_sf_pallas)
from repro.kernels.fp_fan import bp_fan_sf_pallas, fp_fan_sf_pallas
from repro.kernels.fp_modular import (bp_modular_sf_pallas,
                                      fp_modular_sf_pallas,
                                      fp_modular_sf_ref)
from repro.kernels.fp_par import bp_parallel_sf_pallas, fp_parallel_sf_pallas
from repro.kernels.tune import KernelConfig


def _t(fn, *a, reps=2):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(csv_rows: list):
    on_tpu = jax.default_backend() == "tpu"
    mode = "tpu" if on_tpu else "interpret-mode(correctness-only)"
    reps = 5 if on_tpu else 1

    # ---- 3D kernel shape: fp / bp / grad, oracle vs pallas --------------- #
    # Interpret mode executes one Python step per grid point — use a small
    # grid off-TPU so the suite stays inside the harness budget.
    if on_tpu:
        vol = VolumeGeometry(64, 64, 8)
        g = parallel_beam(24, 8, 96, vol)
    else:
        vol = VolumeGeometry(32, 32, 4)
        g = parallel_beam(12, 4, 48, vol)
    f = jnp.asarray(np.random.default_rng(0).normal(
        size=vol.shape).astype(np.float32))
    y = jnp.asarray(np.random.default_rng(1).normal(
        size=g.sino_shape).astype(np.float32))
    t_ref = _t(jax.jit(lambda x: ref.forward(x, g, "sf")), f)
    csv_rows.append(("kernel/fp_par_sf/jnp_oracle", t_ref * 1e6, "cpu-jit"))
    t_bp_ref = _t(jax.jit(lambda p: ref.adjoint(p, g, "sf")), y)
    csv_rows.append(("kernel/bp_par_sf/jnp_oracle", t_bp_ref * 1e6, "cpu-jit"))
    t_pal = _t(lambda x: fp_parallel_sf_pallas(x, g), f, reps=reps)
    csv_rows.append(("kernel/fp_par_sf/pallas", t_pal * 1e6, mode))
    t_bp = _t(lambda p: bp_parallel_sf_pallas(p, g), y, reps=reps)
    csv_rows.append(("kernel/bp_par_sf/pallas", t_bp * 1e6, mode))

    # view-block sweep (the ba knob the autotuner searches)
    for ba in (1, 4):
        t = _t(lambda x: fp_parallel_sf_pallas(
            x, g, config=KernelConfig(ba=ba)), f, reps=reps)
        csv_rows.append((f"kernel/fp_par_sf/pallas_ba{ba}", t * 1e6, mode))

    # mixed precision: bf16 tiles / f32 accumulate.  check_regression pairs
    # every `_bf16` row with its f32 sibling (suffix stripped) and, on TPU,
    # requires the bf16 variant to win on the batched BP rows below.
    t = _t(lambda x: fp_parallel_sf_pallas(x, g, compute_dtype="bfloat16"),
           f, reps=reps)
    csv_rows.append(("kernel/fp_par_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_pal / max(t, 1e-12):.2f}x"))
    t = _t(lambda p: bp_parallel_sf_pallas(p, g, compute_dtype="bfloat16"),
           y, reps=reps)
    csv_rows.append(("kernel/bp_par_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_bp / max(t, 1e-12):.2f}x"))
    # BP stripe reuse (the bs knob): one sinogram stripe stays resident in
    # VMEM across bs gathered-axis output tiles instead of being re-fetched.
    t = _t(lambda p: bp_parallel_sf_pallas(p, g, bs=4), y, reps=reps)
    csv_rows.append(("kernel/bp_par_sf/pallas_bs4", t * 1e6,
                     f"{mode};speedup_vs_bs1={t_bp / max(t, 1e-12):.2f}x"))

    # ---- batched 2D training shape: seed vmap path vs lane packing ------- #
    # The paper's limited-angle DL regime: thin-z volume, single detector
    # row, per-step training batch.  This is where lane packing turns
    # 1/128 lane occupancy into full tiles.
    B = 8
    if on_tpu:
        vol2 = VolumeGeometry(128, 128, 1)
        g2 = parallel_beam(90, 1, 192, vol2)
    else:
        vol2 = VolumeGeometry(32, 32, 1)
        g2 = parallel_beam(12, 1, 48, vol2)
    fb = jnp.asarray(np.random.default_rng(2).normal(
        size=(B,) + vol2.shape).astype(np.float32))
    yb = jnp.asarray(np.random.default_rng(3).normal(
        size=(B,) + g2.sino_shape).astype(np.float32))

    t_vmap = _t(lambda x: jax.vmap(
        lambda s: fp_parallel_sf_pallas(s, g2))(x), fb, reps=reps)
    csv_rows.append((f"kernel/fp2d_b{B}/pallas_vmap_seed", t_vmap * 1e6, mode))
    t_pack = _t(lambda x: fp_parallel_sf_pallas(x, g2), fb, reps=reps)
    csv_rows.append((f"kernel/fp2d_b{B}/pallas_lane_packed", t_pack * 1e6,
                     f"{mode};speedup_vs_vmap={t_vmap / max(t_pack, 1e-12):.2f}x"))

    # batched BP at the same training shape: the memory-bound row the
    # mixed-precision tentpole targets.  f32 lane-packed is the baseline;
    # the `_bf16` sibling adds bf16 tiles AND bs=4 stripe reuse — the
    # acceptance row for the >=1.5x batched-BP speedup (gated on TPU by
    # check_regression's dtype-sibling pass).
    t_bp_pack = _t(lambda p: bp_parallel_sf_pallas(p, g2), yb, reps=reps)
    csv_rows.append((f"kernel/bp2d_b{B}/pallas_lane_packed",
                     t_bp_pack * 1e6, mode))
    t_bp_mp = _t(lambda p: bp_parallel_sf_pallas(
        p, g2, bs=4, compute_dtype="bfloat16"), yb, reps=reps)
    csv_rows.append((f"kernel/bp2d_b{B}/pallas_lane_packed_bf16",
                     t_bp_mp * 1e6,
                     f"{mode};speedup_vs_f32="
                     f"{t_bp_pack / max(t_bp_mp, 1e-12):.2f}x"))

    # forward + VJP (one training step's projector work), both batch paths.
    # Gradients route through the registered matched pair (custom_vjp), so
    # the VJP is the backprojection kernel, not autodiff through pallas_call.
    from repro.kernels import ops

    def loss_ops(x):
        p = ops.forward_project(x, g2, "sf", backend="pallas")
        return 0.5 * jnp.sum((p - yb) ** 2)

    t_grad_vmap = _t(lambda x: jax.grad(
        lambda z: 0.5 * jnp.sum(
            (jax.vmap(lambda s: ops.forward_project(
                s, g2, "sf", backend="pallas"))(z) - yb) ** 2))(x),
        fb, reps=reps)
    csv_rows.append((f"kernel/grad2d_b{B}/pallas_vmap_seed",
                     t_grad_vmap * 1e6, mode))
    t_grad_pack = _t(lambda x: jax.grad(loss_ops)(x), fb, reps=reps)
    csv_rows.append((f"kernel/grad2d_b{B}/pallas_lane_packed",
                     t_grad_pack * 1e6,
                     f"{mode};speedup_vs_vmap="
                     f"{t_grad_vmap / max(t_grad_pack, 1e-12):.2f}x"))

    # ---- fan beam: pallas FP/BP vs oracle, plus the lane-packed batch ---- #
    if on_tpu:
        volf = VolumeGeometry(64, 64, 8)
        gf = fan_beam(24, 8, 96, volf, sod=150.0, sdd=300.0, pixel_width=2.0)
    else:
        volf = VolumeGeometry(32, 32, 4)
        gf = fan_beam(12, 4, 48, volf, sod=80.0, sdd=160.0, pixel_width=2.0)
    ff = jnp.asarray(np.random.default_rng(5).normal(
        size=volf.shape).astype(np.float32))
    yf = jnp.asarray(np.random.default_rng(6).normal(
        size=gf.sino_shape).astype(np.float32))
    t = _t(jax.jit(lambda x: ref.forward(x, gf, "sf")), ff)
    csv_rows.append(("kernel/fp_fan_sf/jnp_oracle", t * 1e6, "cpu-jit"))
    t_fpf = _t(lambda x: fp_fan_sf_pallas(x, gf), ff, reps=reps)
    csv_rows.append(("kernel/fp_fan_sf/pallas", t_fpf * 1e6, mode))
    t_bpf = _t(lambda p: bp_fan_sf_pallas(p, gf), yf, reps=reps)
    csv_rows.append(("kernel/bp_fan_sf/pallas", t_bpf * 1e6, mode))
    t = _t(lambda x: fp_fan_sf_pallas(x, gf, compute_dtype="bfloat16"),
           ff, reps=reps)
    csv_rows.append(("kernel/fp_fan_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_fpf / max(t, 1e-12):.2f}x"))
    t = _t(lambda p: bp_fan_sf_pallas(p, gf, compute_dtype="bfloat16"),
           yf, reps=reps)
    csv_rows.append(("kernel/bp_fan_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_bpf / max(t, 1e-12):.2f}x"))

    # thin-z lane-packed fan batch (seed vmap path vs packed path)
    gf2 = fan_beam(g2.n_angles, 1, g2.n_cols, vol2,
                   sod=4.0 * vol2.radius, sdd=8.0 * vol2.radius,
                   pixel_width=2.0)
    t_vmapf = _t(lambda x: jax.vmap(
        lambda s: fp_fan_sf_pallas(s, gf2))(x), fb, reps=reps)
    csv_rows.append((f"kernel/fp_fan2d_b{B}/pallas_vmap", t_vmapf * 1e6, mode))
    t_packf = _t(lambda x: fp_fan_sf_pallas(x, gf2), fb, reps=reps)
    csv_rows.append((f"kernel/fp_fan2d_b{B}/pallas_lane_packed", t_packf * 1e6,
                     f"{mode};speedup_vs_vmap="
                     f"{t_vmapf / max(t_packf, 1e-12):.2f}x"))

    # ---- cone beam: the Pallas FP/BP matched pair ------------------------ #
    # The BP is the exact transpose of the FP (transposed transaxial
    # contraction + per-element axial matvec in the adjoint direction); the
    # bp_over_fp ratio is the number the CI regression gate tracks.
    if on_tpu:
        volc = VolumeGeometry(64, 64, 16)
        gc = cone_beam(24, 16, 96, volc, sod=150.0, sdd=300.0,
                       pixel_width=2.0, pixel_height=2.0)
    else:
        volc = VolumeGeometry(16, 16, 8)
        gc = cone_beam(4, 8, 24, volc, sod=80.0, sdd=160.0,
                       pixel_width=2.0, pixel_height=2.0)
    fc = jnp.asarray(np.random.default_rng(7).normal(
        size=volc.shape).astype(np.float32))
    yc = jnp.asarray(np.random.default_rng(8).normal(
        size=gc.sino_shape).astype(np.float32))
    t = _t(jax.jit(lambda x: ref.forward(x, gc, "sf")), fc)
    csv_rows.append(("kernel/fp_cone_sf/jnp_oracle", t * 1e6, "cpu-jit"))
    t = _t(jax.jit(lambda p: ref.adjoint(p, gc, "sf")), yc)
    csv_rows.append(("kernel/bp_cone_sf/jnp_oracle", t * 1e6, "cpu-jit"))
    t_fpc = _t(lambda x: fp_cone_sf_pallas(x, gc), fc, reps=reps)
    csv_rows.append(("kernel/fp_cone_sf/pallas", t_fpc * 1e6, mode))
    t_bpc = _t(lambda p: bp_cone_sf_pallas(p, gc), yc, reps=reps)
    csv_rows.append(("kernel/bp_cone_sf/pallas", t_bpc * 1e6,
                     f"{mode};bp_over_fp={t_bpc / max(t_fpc, 1e-12):.2f}x"))
    t = _t(lambda x: fp_cone_sf_pallas(x, gc, compute_dtype="bfloat16"),
           fc, reps=reps)
    csv_rows.append(("kernel/fp_cone_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_fpc / max(t, 1e-12):.2f}x"))
    t = _t(lambda p: bp_cone_sf_pallas(p, gc, compute_dtype="bfloat16"),
           yc, reps=reps)
    csv_rows.append(("kernel/bp_cone_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_bpc / max(t, 1e-12):.2f}x"))

    # ---- modular beam (helical): the Pallas SF matched pair -------------- #
    # The modular pair is the cone pair generalized to per-view frames
    # (scalar-prefetched 24-float rows); a helical trajectory is the
    # canonical workload no fixed-geometry kernel can express.  Both rows
    # are gated by check_regression (and grepped by benchmarks-smoke).
    if on_tpu:
        volm = VolumeGeometry(64, 64, 16)
        gm = helical_beam(1.0, 16.0, 24, 16, 96, volm, sod=150.0, sdd=300.0,
                          pixel_width=2.0, pixel_height=2.0)
    else:
        volm = VolumeGeometry(16, 16, 8)
        gm = helical_beam(1.0, 8.0, 4, 8, 24, volm, sod=80.0, sdd=160.0,
                          pixel_width=2.0, pixel_height=2.0)
    fm = jnp.asarray(np.random.default_rng(11).normal(
        size=volm.shape).astype(np.float32))
    ym = jnp.asarray(np.random.default_rng(12).normal(
        size=gm.sino_shape).astype(np.float32))
    t = _t(jax.jit(lambda x: fp_modular_sf_ref(x, gm)), fm)
    csv_rows.append(("kernel/fp_modular_sf/jnp_oracle", t * 1e6, "cpu-jit"))
    t_fpm = _t(lambda x: fp_modular_sf_pallas(x, gm), fm, reps=reps)
    csv_rows.append(("kernel/fp_modular_sf/pallas", t_fpm * 1e6, mode))
    t_bpm = _t(lambda p: bp_modular_sf_pallas(p, gm), ym, reps=reps)
    csv_rows.append(("kernel/bp_modular_sf/pallas", t_bpm * 1e6,
                     f"{mode};bp_over_fp={t_bpm / max(t_fpm, 1e-12):.2f}x"))
    t = _t(lambda x: fp_modular_sf_pallas(x, gm, compute_dtype="bfloat16"),
           fm, reps=reps)
    csv_rows.append(("kernel/fp_modular_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_fpm / max(t, 1e-12):.2f}x"))
    t = _t(lambda p: bp_modular_sf_pallas(p, gm, compute_dtype="bfloat16"),
           ym, reps=reps)
    csv_rows.append(("kernel/bp_modular_sf/pallas_bf16", t * 1e6,
                     f"{mode};speedup_vs_f32={t_bpm / max(t, 1e-12):.2f}x"))

    # ---- batched multi-row cone: exact view-folded batch vs lane packing - #
    # The ROADMAP's last kernel item: the exact cone pair folds batches into
    # the *grid* (one program per (sample, view)); the packed pair
    # pre-resamples rows axially and lane-packs batch x n_rows like fan.
    # The speedup column is the acceptance number for the packed tentpole.
    from repro.kernels.tune import packed_cone_ok
    Bc = 4
    if on_tpu:
        volp = VolumeGeometry(64, 64, 8)
        gp = cone_beam(24, 8, 96, volp, sod=1000.0, sdd=2000.0,
                       pixel_width=2.0, pixel_height=2.0)
    else:
        volp = VolumeGeometry(16, 16, 4)
        gp = cone_beam(4, 4, 24, volp, sod=200.0, sdd=400.0,
                       pixel_width=2.0, pixel_height=2.0)
    assert packed_cone_ok(gp), cone_packed_row_shift(gp)  # packed-eligible
    fp_b = jnp.asarray(np.random.default_rng(9).normal(
        size=(Bc,) + volp.shape).astype(np.float32))
    yp_b = jnp.asarray(np.random.default_rng(10).normal(
        size=(Bc,) + gp.sino_shape).astype(np.float32))
    t_exact_b = _t(lambda x: fp_cone_sf_pallas(x, gp), fp_b, reps=reps)
    csv_rows.append((f"kernel/fp_cone3d_b{Bc}/pallas_exact_batched",
                     t_exact_b * 1e6, mode))
    t_packed_b = _t(lambda x: fp_cone_packed(x, gp), fp_b, reps=reps)
    csv_rows.append((f"kernel/fp_cone3d_b{Bc}/pallas_packed",
                     t_packed_b * 1e6,
                     f"{mode};speedup_vs_exact="
                     f"{t_exact_b / max(t_packed_b, 1e-12):.2f}x"))
    t_bp_exact_b = _t(lambda p: bp_cone_sf_pallas(p, gp), yp_b, reps=reps)
    csv_rows.append((f"kernel/bp_cone3d_b{Bc}/pallas_exact_batched",
                     t_bp_exact_b * 1e6, mode))
    t_bp_packed_b = _t(lambda p: bp_cone_packed(p, gp), yp_b, reps=reps)
    csv_rows.append((f"kernel/bp_cone3d_b{Bc}/pallas_packed",
                     t_bp_packed_b * 1e6,
                     f"{mode};speedup_vs_exact="
                     f"{t_bp_exact_b / max(t_bp_packed_b, 1e-12):.2f}x"))
    # second batched-BP dtype-gate target: packed cone with bf16 tiles and
    # bs=2 stripe reuse vs its f32 sibling row above.
    t_bp_packed_mp = _t(lambda p: bp_cone_packed(
        p, gp, bs=2, compute_dtype="bfloat16"), yp_b, reps=reps)
    csv_rows.append((f"kernel/bp_cone3d_b{Bc}/pallas_packed_bf16",
                     t_bp_packed_mp * 1e6,
                     f"{mode};speedup_vs_f32="
                     f"{t_bp_packed_b / max(t_bp_packed_mp, 1e-12):.2f}x"))

    # ---- 2D production-ish slice (the paper's 512^2 limited-angle) ------- #
    vol3 = VolumeGeometry(256, 256, 1)
    g3 = parallel_beam(180, 1, 384, vol3)
    f3 = jnp.asarray(np.random.default_rng(4).normal(
        size=vol3.shape).astype(np.float32))
    t2 = _t(jax.jit(lambda x: ref.forward(x, g3, "sf")), f3)
    csv_rows.append(("kernel/fp_256x256x180", t2 * 1e6, "cpu-jit"))

"""Weak-scaling benchmark for the distributed projector subsystem.

``dist/<op>/ws<n>`` rows time the sharded FP, overlap-comm BP, and a full
distributed SIRT loop on angle-sharded meshes of 1/2/4/8 shards with the
*per-shard* work held constant (n_angles grows with the mesh) — classic
weak scaling, so a perfectly scaling stack prints a flat column.

The regression gate (``check_regression``) normalizes every ``ws<n>`` row
by the same op's ``ws1`` row from the same run, which cancels machine
speed and makes the committed baseline a *scaling-shape* gate: a PR that
breaks comm overlap or serializes the mesh shows up as ws4/ws8 drifting
up relative to ws1, on any runner.  On the CI CPU the 8 "devices" are
forced host threads on a shared core, so the absolute column is ~linear
in shards (all shards timeshare one core); the gate tracks the *shape* of
that line, and real parallel speedups are a TPU-pod measurement.

Emits nothing when fewer than 8 devices are visible (dev boxes without
``XLA_FLAGS=--xla_force_host_platform_device_count=8``): the gate only
compares dist rows when the fresh CSV contains the suite.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ProjectorSpec, VolumeGeometry, parallel_beam
from repro.core.distributed import distribute
from repro.recon.sirt import sirt

SHARD_COUNTS = (1, 2, 4, 8)
ANGLES_PER_SHARD = 8


def _t(fn, *a, reps=2):
    out = fn(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(csv_rows: list):
    if jax.device_count() < max(SHARD_COUNTS):
        return
    backend = jax.default_backend()
    vol = VolumeGeometry(24, 24, 8)
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.normal(size=vol.shape), jnp.float32)

    for ws in SHARD_COUNTS:
        mesh = jax.make_mesh((ws, 1), ("data", "model"),
                             devices=jax.devices()[:ws])
        g = parallel_beam(ANGLES_PER_SHARD * ws, 8, 32, vol)
        dp = distribute(ProjectorSpec(g), mesh)
        tag = f"{backend}-weak-{ws}shard"

        fv = dp.shard_volume(f)
        csv_rows.append((f"dist/fp_par/ws{ws}", _t(dp.fp, fv) * 1e6, tag))
        y = dp(fv)
        ys = dp.shard_sino(y)
        csv_rows.append((f"dist/bp_par/ws{ws}", _t(dp.bp, ys) * 1e6, tag))

        # the eager sirt loop would re-trace its scan per call: jit the
        # whole solve once so the row times the mesh program, not tracing
        solve = jax.jit(lambda sino, dp=dp: sirt(dp, sino, n_iters=4).image)
        csv_rows.append((f"dist/sirt_par/ws{ws}",
                         _t(solve, ys) * 1e6, tag))

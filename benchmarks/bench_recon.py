"""Reconstruction-pipeline benchmark (paper §3 'end-to-end reconstruction'):
FBP / SIRT / CGLS / FISTA-TV wall time + PSNR on Shepp-Logan."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Projector, ProjectorSpec, VolumeGeometry, parallel_beam
from repro.data.metrics import psnr
from repro.data.phantoms import shepp_logan_2d
from repro.recon import cgls, fista_tv, sirt


def run(csv_rows: list):
    vol = VolumeGeometry(128, 128, 1)
    geom = parallel_beam(180, 1, 192, vol)
    proj = Projector(ProjectorSpec(geom, model="sf"))
    f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
    y = proj(f)

    algs = {
        "fbp": lambda: proj.fbp(y),
        "sirt50": lambda: sirt(proj, y, n_iters=50).image,
        "cgls20": lambda: cgls(proj, y, n_iters=20).image,
        "fista30": lambda: fista_tv(proj, y, n_iters=30, beta=1e-4).image,
    }
    for name, fn in algs.items():
        jfn = jax.jit(fn)
        out = jfn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        out = jfn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        q = psnr(out, f, peak=0.02)
        csv_rows.append((f"recon/{name}", dt * 1e6, f"psnr={q:.2f}dB"))

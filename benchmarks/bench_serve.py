"""Serving throughput bench: geometry-bucketed dynamic batching vs a serial
per-request loop (``CTServer(max_batch=1)``), per latency tier.

The scenario is the ROADMAP's recon-as-a-service shape: a burst of small
single-slice recon requests sharing one protocol geometry.  The batched
server packs them onto the lane axis in one compiled dispatch; the serial
server answers them one by one through the same solver and warm path — the
measured ratio is purely the packing win.

Rows (us per recon, lower is better):
    serve/<tier>/serial_us_per_recon     calibration row for the tier
    serve/<tier>/batched_us_per_recon    gated: serial/batched >= 4x
    serve/<tier>/batched_p50_us          per-request latency percentiles
    serve/<tier>/batched_p99_us          (submit -> answered, queue incl.)

On CPU the quality tier shows the full packing win (an iterative solve is
many small dispatches per request, all amortized by the pack); single-shot
FBP is bounded by its own XLA compute, which batching cannot shrink off-TPU,
so the interactive gate is advisory on CPU (see check_regression.py).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Projector, ProjectorSpec, VolumeGeometry, parallel_beam
from repro.data.phantoms import shepp_logan_2d
from repro.launch.ct_serve import CTServer, ReconRequest

N_REQUESTS = 64
MAX_BATCH = 16
#: (tier, solver, kwargs, (nx, n_angles, n_cols)) — per-request shapes are
#: deliberately small (single-slice protocol scans): that is the regime the
#: batcher exists for.
SCENARIOS = (
    ("interactive", "fbp", {}, (16, 12, 24)),
    ("quality", "sirt", {"n_iters": 10}, (32, 24, 48)),
)


def _drive(server: CTServer, spec, sino, solver, kwargs):
    """Submit a burst of identical-protocol requests, drain, and return
    (wall seconds, sorted per-request latencies in us)."""
    t0 = time.perf_counter()
    rids = [server.submit(ReconRequest(spec=spec, sino=sino, solver=solver,
                                       solver_kwargs=dict(kwargs)))
            for _ in range(N_REQUESTS)]
    done = server.drain()
    wall = time.perf_counter() - t0
    assert all(done[r].ok for r in rids), \
        [done[r].error for r in rids if not done[r].ok][:1]
    lats = np.sort([done[r].latency_s * 1e6 for r in rids])
    return wall, lats


def run(csv_rows: list):
    backend = jax.default_backend()
    for tier, solver, kwargs, (nx, n_angles, n_cols) in SCENARIOS:
        vol = VolumeGeometry(nx, nx, 1)
        spec = ProjectorSpec(parallel_beam(n_angles, 1, n_cols, vol))
        f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
        sino = Projector(spec)(f)

        serial = CTServer(max_batch=1)
        batched = CTServer(max_batch=MAX_BATCH)
        for srv in (serial, batched):
            srv.warm(spec, solver, kwargs)
            _drive(srv, spec, sino, solver, kwargs)   # shake out host caches

        wall_serial, _ = _drive(serial, spec, sino, solver, kwargs)
        wall_batched, lats = _drive(batched, spec, sino, solver, kwargs)

        us_serial = wall_serial / N_REQUESTS * 1e6
        us_batched = wall_batched / N_REQUESTS * 1e6
        speedup = us_serial / max(us_batched, 1e-9)
        p50 = float(np.percentile(lats, 50))
        p99 = float(np.percentile(lats, 99))
        csv_rows.append((f"serve/{tier}/serial_us_per_recon", us_serial,
                         f"{backend} batch=1 n={N_REQUESTS}"))
        csv_rows.append((f"serve/{tier}/batched_us_per_recon", us_batched,
                         f"{backend} batch={MAX_BATCH} "
                         f"speedup={speedup:.1f}x"))
        csv_rows.append((f"serve/{tier}/batched_p50_us", p50,
                         f"{backend} latency"))
        csv_rows.append((f"serve/{tier}/batched_p99_us", p99,
                         f"{backend} latency"))

"""Paper Table 1: forward/back-projection performance.

On this CPU container we (a) measure wall time at CPU-feasible reduced
shapes for every geometry x model x direction, and (b) report the projected
TPU-v5e time for the paper's full shapes from the roofline model (SF is
HBM-bound; see EXPERIMENTS.md §Perf-CT).  Output CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.leap_ct import table1_geometries
from repro.core import Projector
from repro.launch.roofline import HBM_BW


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def projected_tpu_seconds(geom, model="sf") -> float:
    """SF projection is HBM-bound: traffic ~ footprint-K reads of the volume
    + one sinogram write (+ z-matmul traffic)."""
    v = geom.vol
    K = geom.max_footprint_cols()
    vol_bytes = v.nx * v.ny * v.nz * 4
    sino_bytes = int(np.prod(geom.sino_shape)) * 4
    # per angle: one streamed pass over the (z-contracted) volume + tile output
    traffic = geom.n_angles * (v.nx * v.ny * max(geom.n_rows, v.nz) * 4) \
        + sino_bytes + vol_bytes
    return traffic / HBM_BW


def run(csv_rows: list):
    cells = table1_geometries(reduced=True)
    full = table1_geometries(reduced=False)
    for name, geom in cells.items():
        proj = Projector(geom, "sf")
        f = jnp.asarray(np.random.default_rng(0).normal(
            size=geom.vol.shape).astype(np.float32))
        fp = jax.jit(lambda x: proj(x))
        t_fp = _time(fp, f)
        y = fp(f)
        bp = jax.jit(lambda s: proj.T(s))
        t_bp = _time(bp, y)
        tpu_est = projected_tpu_seconds(full[name])
        csv_rows.append((f"table1/{name}/fp", t_fp * 1e6,
                         f"tpu_v5e_est_full={tpu_est:.3f}s"))
        csv_rows.append((f"table1/{name}/bp", t_bp * 1e6,
                         f"reduced_shape={geom.vol.shape}x{geom.n_angles}"))

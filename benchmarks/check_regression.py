"""Benchmark regression gate: diff a fresh ``bench_kernels`` CSV against the
committed ``benchmarks/baseline.json``.

CI runners and developer machines differ in absolute speed, so the gate
compares *normalized* times: every FP/BP kernel row is divided by a
calibration row measured in the same run *and executed through the same
stack* — jitted rows (``cpu-jit`` derived tag) normalize by the jnp-oracle
parallel FP, interpret-mode/TPU Pallas rows by the Pallas parallel FP —
cancelling both machine speed and the machine-dependent interpreter-vs-XLA
ratio to first order.  A row is a regression when

    (fresh_us / fresh_cal) > FAIL_RATIO * baseline_norm

and a *missing* row (present in the baseline, absent from the fresh CSV) is
an API-drift failure — a renamed entry point or a bench that stopped running
is exactly what this gate exists to catch.  Ratios between WARN_RATIO and
FAIL_RATIO print as warnings only (CPU noise on shared runners).

Usage:
    PYTHONPATH=src python -m benchmarks.run --only kernels > fresh.csv
    python -m benchmarks.check_regression fresh.csv              # gate
    python -m benchmarks.check_regression fresh.csv --write-baseline
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys
from typing import Dict, Tuple

BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"
# Per-stack calibration rows: jitted rows drift with XLA/CPU speed, Pallas
# rows (interpret mode on CI) with Python-interpreter speed — normalizing
# each class by its own calibration row keeps the ratios machine-portable.
CAL_JIT = "kernel/fp_par_sf/jnp_oracle"
CAL_PALLAS = "kernel/fp_par_sf/pallas"
GATE = re.compile(r"^kernel/(fp|bp)")
FAIL_RATIO = 1.5
WARN_RATIO = 1.15
# Sub-millisecond jitted rows are dominated by timer/scheduler jitter, not
# kernel speed (observed: the ~800us bp_par oracle row spanning 742-2428us
# across back-to-back idle runs of the same binary).  Rows this small can't
# carry a meaningful ratio, so they warn instead of failing; the missing-row
# (API drift) check still applies to them in full.
JITTER_FLOOR_US = 5000.0


def parse_csv(path: str) -> Dict[str, Tuple[float, str]]:
    """``name,us_per_call,derived`` rows (the benchmarks.run contract) as
    ``{name: (us, derived)}``; error sentinels (us < 0) are dropped so they
    register as missing."""
    rows: Dict[str, Tuple[float, str]] = {}
    for line in pathlib.Path(path).read_text().splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        if us > 0:
            rows[parts[0]] = (us, parts[2] if len(parts) > 2 else "")
    return rows


def _norm(fresh: Dict[str, Tuple[float, str]], name: str) -> float:
    us, derived = fresh[name]
    cal = CAL_JIT if derived.startswith("cpu-jit") else CAL_PALLAS
    return us / fresh[cal][0]


def write_baseline(fresh: Dict[str, Tuple[float, str]],
                   path: pathlib.Path) -> None:
    entries = {
        name: {"norm": round(_norm(fresh, name), 4), "us": round(us, 1)}
        for name, (us, _) in sorted(fresh.items()) if GATE.match(name)
    }
    payload = {
        "_meta": {
            "calibration_rows": {"cpu-jit": CAL_JIT, "pallas": CAL_PALLAS},
            "fail_ratio": FAIL_RATIO,
            "note": "norm = us / us(same-stack calibration row), same run; "
                    "regenerate with check_regression --write-baseline",
        },
        "rows": entries,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {path} ({len(entries)} gated rows)")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", help="fresh bench_kernels CSV to check")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from the CSV instead")
    args = ap.parse_args()

    fresh = parse_csv(args.csv)
    for cal in (CAL_JIT, CAL_PALLAS):
        if cal not in fresh:
            print(f"FAIL: calibration row {cal!r} missing from {args.csv}")
            return 1
    if args.write_baseline:
        write_baseline(fresh, pathlib.Path(args.baseline))
        return 0

    baseline = json.loads(pathlib.Path(args.baseline).read_text())["rows"]
    fails, warns = [], []
    for name, entry in baseline.items():
        if name not in fresh:
            fails.append(f"{name}: missing from fresh run (API drift?)")
            continue
        norm = _norm(fresh, name)
        ratio = norm / entry["norm"]
        line = (f"{name}: {ratio:.2f}x baseline "
                f"(norm {norm:.3f} vs {entry['norm']:.3f})")
        tiny = (fresh[name][0] < JITTER_FLOOR_US
                and entry.get("us", JITTER_FLOOR_US) < JITTER_FLOOR_US)
        if ratio > FAIL_RATIO and not tiny:
            fails.append(line)
        elif ratio > WARN_RATIO or (ratio > FAIL_RATIO and tiny):
            warns.append(line)
    for name in sorted(set(fresh) - set(baseline)):
        if GATE.match(name):
            warns.append(f"{name}: new row not in baseline "
                         f"(regenerate with --write-baseline)")

    for w in warns:
        print(f"WARN: {w}")
    for f in fails:
        print(f"FAIL: {f}")
    if fails:
        print(f"{len(fails)} regression(s) > {FAIL_RATIO}x — if intentional, "
              f"regenerate benchmarks/baseline.json with --write-baseline")
        return 1
    print(f"benchmark gate OK ({len(baseline)} rows checked, "
          f"{len(warns)} warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

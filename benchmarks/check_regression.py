"""Benchmark regression gate: diff a fresh ``bench_kernels`` CSV against the
committed ``benchmarks/baseline.json``.

CI runners and developer machines differ in absolute speed, so the gate
compares *normalized* times: every FP/BP kernel row is divided by a
calibration row measured in the same run *and executed through the same
stack* — jitted rows (``cpu-jit`` derived tag) normalize by the jnp-oracle
parallel FP, interpret-mode/TPU Pallas rows by the Pallas parallel FP —
cancelling both machine speed and the machine-dependent interpreter-vs-XLA
ratio to first order.  A row is a regression when

    (fresh_us / fresh_cal) > FAIL_RATIO * baseline_norm

and a *missing* row (present in the baseline, absent from the fresh CSV) is
an API-drift failure — a renamed entry point or a bench that stopped running
is exactly what this gate exists to catch.  Ratios between WARN_RATIO and
FAIL_RATIO print as warnings only (CPU noise on shared runners).

A second pass gates the mixed-precision rows: every ``*_bf16`` row is paired
with its f32 sibling (suffix stripped) and, on the batched BP rows (the
memory-bound shapes the bf16 tentpole targets), the bf16 variant must be
*faster* than f32 — but only when the row was measured on real TPU and sits
above the jitter floor.  Interpret-mode runs (CI CPU) print the comparison
as advisory warnings: interpreter per-element cost swamps the HBM-bandwidth
effect bf16 tiles exist to exploit, so a CPU "slower" verdict is noise.

A third pass gates the serving rows (``serve/<tier>/...`` from
``bench_serve``): every tier's batched us/recon must beat the same tier's
serial per-request loop by ``SERVE_MIN_SPEEDUP`` — enforced everywhere for
the iterative ``quality`` tier, TPU-only (advisory on CPU) for the
single-shot ``interactive`` tier.  Serve rows normalize by their own tier's
serial row, so the baseline comparison stays machine-portable for them too.

Distributed weak-scaling rows (``dist/<op>/ws<n>`` from
``bench_distributed``) normalize by the same op's ``ws1`` row, gating the
scaling *shape* (see DIST_GATE below); they are only compared when the
fresh CSV ran the suite (it needs 8 visible devices).

A fourth pass gates reconstruction *quality* (``quality/<geom>/<metric>``
from ``bench_data_consistency``): the value column is a metric (PSNR dB /
SSIM / relative DC residual), not a latency, so these rows skip the
normalized-ratio machinery entirely and use a floor-style rule instead —
PSNR/SSIM must not drop below ``baseline - tolerance`` and the DC residual
must not rise above ``baseline + tolerance`` (see QUALITY_TOL).  Fixed
seeds make the tiny training schedule reproducible; the tolerances absorb
cross-machine XLA codegen jitter while still failing loudly when a kernel,
the EMA path, or the refinement loop breaks (those lose several dB).

Usage:
    PYTHONPATH=src python -m benchmarks.run --only kernels > fresh.csv
    python -m benchmarks.check_regression fresh.csv              # gate
    python -m benchmarks.check_regression r1.csv r2.csv r3.csv r4.csv \
        --write-baseline     # per-row median across repeated runs

Baseline rows are only compared for suites present in the fresh CSV, so a
kernels-only CSV and a serve-only CSV both gate cleanly; CI concatenates
both suites into one CSV before gating.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import statistics
import sys
from typing import Dict, List, Tuple

BASELINE = pathlib.Path(__file__).resolve().parent / "baseline.json"
# Per-stack calibration rows: jitted rows drift with XLA/CPU speed, Pallas
# rows (interpret mode on CI) with Python-interpreter speed — normalizing
# each class by its own calibration row keeps the ratios machine-portable.
CAL_JIT = "kernel/fp_par_sf/jnp_oracle"
CAL_PALLAS = "kernel/fp_par_sf/pallas"
GATE = re.compile(r"^kernel/(fp|bp)")
FAIL_RATIO = 1.5
WARN_RATIO = 1.15
# Sub-millisecond jitted rows are dominated by timer/scheduler jitter, not
# kernel speed (observed: the ~800us bp_par oracle row spanning 742-2428us
# across back-to-back idle runs of the same binary).  Rows this small can't
# carry a meaningful ratio, so they warn instead of failing; the missing-row
# (API drift) check still applies to them in full.
JITTER_FLOOR_US = 5000.0
# Mixed-precision sibling gate: bf16 rows must beat f32 on the batched BP
# shapes (bp2d_b8, bp_cone3d_b4, ...).  DTYPE_TARGET is the tentpole's
# acceptance speedup — below it the row warns, at/below 1.0x it fails
# (TPU-derived rows above the jitter floor only).
BF16_SUFFIX = "_bf16"
BATCHED_BP = re.compile(r"^kernel/bp[^/]*_b\d+/")
DTYPE_TARGET = 1.5
# Serving throughput gate: serve/<tier>/batched_us_per_recon must beat the
# same tier's serial row by SERVE_MIN_SPEEDUP.  The quality tier (iterative
# solvers — many small dispatches per request, all amortized by the pack) is
# enforced on every backend; the interactive tier (single-shot FBP, whose
# XLA compute batching cannot shrink off-TPU) is enforced on TPU and
# advisory on CPU, mirroring the bf16 sibling gate's reasoning.  Serve rows
# normalize by their tier's serial row (same run, same stack), so the
# norm-vs-baseline pass stays machine-portable for them too.
SERVE_GATE = re.compile(r"^serve/")
SERVE_ROW = re.compile(r"^serve/(?P<tier>[^/]+)/(?P<kind>[^/]+)$")
SERVE_MIN_SPEEDUP = 4.0
SERVE_CPU_GATED_TIERS = ("quality",)
# Distributed weak-scaling rows (``dist/<op>/ws<n>`` from bench_distributed):
# every row normalizes by the same op's single-shard ``ws1`` row from the
# same run, so the committed baseline gates the *scaling shape* (ws8
# drifting up vs ws1 = broken comm overlap or a serialized mesh) and stays
# machine-portable — absolute mesh speed varies wildly between a CPU forcing
# 8 host devices onto one core and a real pod.
DIST_GATE = re.compile(r"^dist/")
DIST_ROW = re.compile(r"^dist/(?P<op>[^/]+)/ws(?P<n>\d+)$")
# Reconstruction-quality rows (``quality/<geom>/<metric>`` from
# bench_data_consistency): floor-gated on the metric *value*.  Each metric
# kind maps to (direction, tolerance): "floor" fails when
# fresh < baseline - tol, "ceiling" when fresh > baseline + tol.  PSNR
# tolerance is deliberately wider than run-to-run seed noise (fixed seeds)
# but far tighter than any real break: a mis-ordered EMA update, a wrong
# kernel adjoint, or a dead refinement loop each cost several dB.
QUALITY_GATE = re.compile(r"^quality/")
QUALITY_ROW = re.compile(r"^quality/(?P<geom>[^/]+)/(?P<metric>[^/]+)$")
QUALITY_TOL = {
    "psnr": ("floor", 1.5),       # dB
    "ssim": ("floor", 0.05),
    "dc": ("ceiling", 0.05),      # relative residual
}
# The gated row-name prefixes, in one place: RL007 and the CI smoke job
# both consume this (via expected_rows / --list-expected-rows) instead of
# keeping their own lists.
GATED_PREFIXES = ("kernel/", "serve/", "dist/", "quality/")


def _quality_rule(name: str):
    """(direction, tolerance) for a quality row, from its metric prefix."""
    m = QUALITY_ROW.match(name)
    if m:
        for prefix, rule in QUALITY_TOL.items():
            if m.group("metric").startswith(prefix):
                return rule
    return None


def expected_rows(prefixes: Tuple[str, ...] = (),
                  baseline_path: pathlib.Path = BASELINE) -> List[str]:
    """The gated row names from the committed baseline — the single source
    of truth for "which bench rows must exist".  CI's smoke jobs and the
    repro-lint RL007 pass both consume this instead of keeping their own
    hand-maintained row lists."""
    rows = sorted(json.loads(pathlib.Path(baseline_path).read_text())["rows"])
    if prefixes:
        rows = [r for r in rows if any(r.startswith(p) for p in prefixes)]
    return rows


def parse_csv(path: str) -> Dict[str, Tuple[float, str]]:
    """``name,us_per_call,derived`` rows (the benchmarks.run contract) as
    ``{name: (us, derived)}``; error sentinels (us < 0) are dropped so they
    register as missing."""
    rows: Dict[str, Tuple[float, str]] = {}
    for line in pathlib.Path(path).read_text().splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) < 2 or parts[0] == "name":
            continue
        try:
            us = float(parts[1])
        except ValueError:
            continue
        if us > 0:
            rows[parts[0]] = (us, parts[2] if len(parts) > 2 else "")
    return rows


def _norm(fresh: Dict[str, Tuple[float, str]], name: str) -> float:
    us, derived = fresh[name]
    m = SERVE_ROW.match(name)
    d = DIST_ROW.match(name)
    if m:
        cal = f"serve/{m.group('tier')}/serial_us_per_recon"
    elif d:
        cal = f"dist/{d.group('op')}/ws1"
    else:
        cal = CAL_JIT if derived.startswith("cpu-jit") else CAL_PALLAS
    return us / fresh[cal][0]


def check_serve_throughput(fresh: Dict[str, Tuple[float, str]]):
    """Enforce the dynamic-batching win: batched us/recon vs the same
    tier's serial loop."""
    fails, warns = [], []
    for name in sorted(fresh):
        m = SERVE_ROW.match(name)
        if not m or m.group("kind") != "batched_us_per_recon":
            continue
        tier = m.group("tier")
        serial = f"serve/{tier}/serial_us_per_recon"
        if serial not in fresh:
            fails.append(f"{name}: serial sibling row {serial!r} missing "
                         f"(API drift?)")
            continue
        us, derived = fresh[name]
        speedup = fresh[serial][0] / max(us, 1e-9)
        on_tpu = derived.startswith("tpu")
        line = (f"{name}: {speedup:.1f}x vs serial loop "
                f"(target {SERVE_MIN_SPEEDUP}x)")
        if speedup >= SERVE_MIN_SPEEDUP:
            continue
        if on_tpu or tier in SERVE_CPU_GATED_TIERS:
            fails.append(line)
        else:
            warns.append(line + " — advisory off-TPU (single-shot compute "
                         "is not shrunk by packing on CPU)")
    return fails, warns


def check_dtype_siblings(fresh: Dict[str, Tuple[float, str]]):
    """Pair every ``*_bf16`` row with its f32 sibling.  Batched BP rows are
    the enforced ones; everything else is informational."""
    fails, warns = [], []
    for name in sorted(fresh):
        if not name.endswith(BF16_SUFFIX) or not GATE.match(name):
            continue
        sib = name[: -len(BF16_SUFFIX)]
        if sib not in fresh:
            fails.append(f"{name}: f32 sibling row {sib!r} missing "
                         f"(API drift?)")
            continue
        us, derived = fresh[name]
        sib_us = fresh[sib][0]
        speedup = sib_us / max(us, 1e-9)
        line = f"{name}: {speedup:.2f}x vs f32 sibling ({us:.0f}us)"
        if not BATCHED_BP.match(name):
            continue                       # only batched BP rows are gated
        if not derived.startswith("tpu") or us < JITTER_FLOOR_US:
            if speedup < DTYPE_TARGET:
                warns.append(line + " — advisory (interpret mode or "
                             "sub-jitter row)")
        elif speedup <= 1.0:
            fails.append(line + f" — bf16 must beat f32 on batched BP "
                         f"(target {DTYPE_TARGET}x)")
        elif speedup < DTYPE_TARGET:
            warns.append(line + f" — below the {DTYPE_TARGET}x target")
    return fails, warns


def write_baseline(runs: List[Dict[str, Tuple[float, str]]],
                   path: pathlib.Path) -> None:
    """Per-row median of the per-run *norms* (each run normalizes by its own
    calibration row first, so run-to-run machine drift cancels before the
    median is taken)."""
    names = sorted(set().union(*[set(r) for r in runs]))
    entries = {}
    for name in names:
        present = [r for r in runs if name in r]
        if QUALITY_GATE.match(name):
            # Quality rows gate on the metric value itself (no calibration
            # row, no latency normalization) — see QUALITY_TOL.
            entries[name] = {
                "value": round(statistics.median(r[name][0]
                                                 for r in present), 4),
                "runs": len(present),
            }
            continue
        if not (GATE.match(name) or SERVE_GATE.match(name)
                or DIST_GATE.match(name)):
            continue
        entries[name] = {
            "norm": round(statistics.median(_norm(r, name)
                                            for r in present), 4),
            "us": round(statistics.median(r[name][0] for r in present), 1),
            "runs": len(present),
        }
    payload = {
        "_meta": {
            "calibration_rows": {"cpu-jit": CAL_JIT, "pallas": CAL_PALLAS},
            "fail_ratio": FAIL_RATIO,
            "note": "norm = median over runs of us / us(same-stack "
                    "calibration row, same run); regenerate with "
                    "check_regression r1.csv r2.csv ... --write-baseline",
        },
        "rows": entries,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {path} ({len(entries)} gated rows, "
          f"median over {len(runs)} run(s))")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("csv", nargs="*",
                    help="fresh bench_kernels CSV(s); the gate checks the "
                         "first, --write-baseline medians across all.  With "
                         "--list-expected-rows these are row-name prefixes "
                         "(e.g. 'kernel/' 'serve/') instead")
    ap.add_argument("--baseline", default=str(BASELINE))
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from the CSV(s) instead")
    ap.add_argument("--list-expected-rows", action="store_true",
                    help="print the gated baseline row names (one per line, "
                         "optionally filtered by prefix args) and exit — "
                         "machine-readable source for CI smoke checks and "
                         "repro-lint RL007")
    args = ap.parse_args()

    if args.list_expected_rows:
        for row in expected_rows(tuple(args.csv),
                                 pathlib.Path(args.baseline)):
            print(row)
        return 0
    if not args.csv:
        ap.error("at least one CSV is required unless --list-expected-rows")

    runs = [parse_csv(p) for p in args.csv]
    for path, run in zip(args.csv, runs):
        # Calibration rows are required only for the row classes present
        # (a serve-only CSV needs no kernel calibration and vice versa).
        if any(GATE.match(n) for n in run):
            for cal in (CAL_JIT, CAL_PALLAS):
                if cal not in run:
                    print(f"FAIL: calibration row {cal!r} missing "
                          f"from {path}")
                    return 1
        for tier in {m.group("tier") for m in map(SERVE_ROW.match, run)
                     if m}:
            cal = f"serve/{tier}/serial_us_per_recon"
            if cal not in run:
                print(f"FAIL: calibration row {cal!r} missing from {path}")
                return 1
        for op in {d.group("op") for d in map(DIST_ROW.match, run) if d}:
            cal = f"dist/{op}/ws1"
            if cal not in run:
                print(f"FAIL: calibration row {cal!r} missing from {path}")
                return 1
    if args.write_baseline:
        write_baseline(runs, pathlib.Path(args.baseline))
        return 0
    fresh = runs[0]

    baseline = json.loads(pathlib.Path(args.baseline).read_text())["rows"]
    fails, warns = [], []
    # A class of baseline rows is only compared when the fresh CSV ran that
    # suite at all (a kernels-only dev run shouldn't fail on serve rows);
    # CI merges the kernels + serve CSVs so drift in either still fails.
    has_kernel = any(GATE.match(n) for n in fresh)
    has_serve = any(SERVE_GATE.match(n) for n in fresh)
    has_dist = any(DIST_GATE.match(n) for n in fresh)
    has_quality = any(QUALITY_GATE.match(n) for n in fresh)
    for name, entry in baseline.items():
        if GATE.match(name) and not has_kernel:
            continue
        if SERVE_GATE.match(name) and not has_serve:
            continue
        if DIST_GATE.match(name) and not has_dist:
            continue
        if QUALITY_GATE.match(name) and not has_quality:
            continue
        if name not in fresh:
            fails.append(f"{name}: missing from fresh run (API drift?)")
            continue
        if QUALITY_GATE.match(name):
            rule = _quality_rule(name)
            if rule is None:       # unknown metric kind: inventory-only
                continue
            direction, tol = rule
            value, base = fresh[name][0], entry["value"]
            if direction == "floor" and value < base - tol:
                fails.append(f"{name}: {value:.4g} below quality floor "
                             f"{base:.4g} - {tol:g}")
            elif direction == "ceiling" and value > base + tol:
                fails.append(f"{name}: {value:.4g} above quality ceiling "
                             f"{base:.4g} + {tol:g}")
            continue
        norm = _norm(fresh, name)
        ratio = norm / entry["norm"]
        line = (f"{name}: {ratio:.2f}x baseline "
                f"(norm {norm:.3f} vs {entry['norm']:.3f})")
        tiny = (fresh[name][0] < JITTER_FLOOR_US
                and entry.get("us", JITTER_FLOOR_US) < JITTER_FLOOR_US)
        if ratio > FAIL_RATIO and not tiny:
            fails.append(line)
        elif ratio > WARN_RATIO or (ratio > FAIL_RATIO and tiny):
            warns.append(line)
    for name in sorted(set(fresh) - set(baseline)):
        if (GATE.match(name) or SERVE_GATE.match(name)
                or DIST_GATE.match(name) or QUALITY_GATE.match(name)):
            warns.append(f"{name}: new row not in baseline "
                         f"(regenerate with --write-baseline)")

    dt_fails, dt_warns = check_dtype_siblings(fresh)
    fails.extend(dt_fails)
    warns.extend(dt_warns)
    sv_fails, sv_warns = check_serve_throughput(fresh)
    fails.extend(sv_fails)
    warns.extend(sv_warns)

    for w in warns:
        print(f"WARN: {w}")
    for f in fails:
        print(f"FAIL: {f}")
    if fails:
        print(f"{len(fails)} gate failure(s) (latency > {FAIL_RATIO}x norm, "
              f"quality past its floor/ceiling, or a missing row) — if "
              f"intentional, regenerate benchmarks/baseline.json with "
              f"--write-baseline")
        return 1
    print(f"benchmark gate OK ({len(baseline)} rows checked, "
          f"{len(warns)} warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""The LM side of the framework: train a reduced assigned-architecture config
with the production step/sharding/checkpoint machinery, then serve greedy
decodes from the trained weights.

    PYTHONPATH=src python examples/lm_train_serve.py --arch qwen3-0.6b --steps 40
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop
from repro.launch.steps import make_serve_step
from repro.models import model as MD


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    mesh = make_local_mesh()
    pipe = TokenPipeline(cfg.vocab_size, 128, 8)
    params, losses = train_loop(cfg, mesh, pipe, args.steps, args.ckpt_dir)
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    serve = jax.jit(make_serve_step(cfg))
    B, ctx = 2, 64
    cache = MD.init_cache(cfg, B, ctx)
    tok = jnp.zeros((B,), jnp.int32)
    out = []
    for t in range(16):
        tok, lg, cache = serve(params, cache, tok, jnp.asarray(t, jnp.int32))
        out.append(int(tok[0]))
    print("greedy decode:", out)


if __name__ == "__main__":
    main()

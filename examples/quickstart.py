"""Quickstart: the library in 40 lines — build a geometry, project a phantom,
reconstruct with FBP and SIRT, and take a gradient through the projector.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Projector, ProjectorSpec, VolumeGeometry, parallel_beam
from repro.data.metrics import psnr
from repro.data.phantoms import shepp_logan_2d
from repro.recon import sirt

# 1. describe the scanner (mm units, like the paper)
vol = VolumeGeometry(nx=128, ny=128, nz=1, dx=1.0, dy=1.0, dz=1.0)
geom = parallel_beam(n_angles=180, n_rows=1, n_cols=192, vol=vol,
                     pixel_width=1.0, angular_range=180.0)

# 2. a differentiable projector.  The ProjectorSpec is the one frozen
#    description of the operator (geometry + model + backend + precision);
#    it doubles as the op-cache key and the serving bucket key.
spec = ProjectorSpec(geom, model="sf")  # Separable Footprint model
proj = Projector(spec)

# 3. forward project a phantom
f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02   # 1/mm
sino = proj(f)
print(f"volume {f.shape} -> sinogram {sino.shape}")

# 4. reconstruct — iterative solvers take the spec (or the projector)
#    and return a ReconResult(image, iterations, residual_history)
rec_fbp = proj.fbp(sino)
res = sirt(spec, sino, n_iters=50)
print(f"FBP  PSNR {psnr(rec_fbp, f, 0.02):.2f} dB")
print(f"SIRT PSNR {psnr(res.image, f, 0.02):.2f} dB "
      f"(residual {float(res.final_residual):.3g} "
      f"after {res.iterations} iters)")

# 5. gradients flow through the projector (the paper's whole point):
loss = lambda x: 0.5 * jnp.sum((proj(x) - sino) ** 2)
g = jax.grad(loss)(jnp.zeros_like(f))
expected = proj.T(proj(jnp.zeros_like(f)) - sino)
print("grad == A^T(Ax - y):",
      bool(jnp.allclose(g, expected, rtol=1e-4, atol=1e-5)))

"""Helical (spiral) cone-beam reconstruction through the modular SF pair.

A helical trajectory — source orbiting while translating along the rotation
axis — cannot be expressed by the fixed parallel/fan/cone geometries; it is
the canonical *modular* workload.  ``helical_beam`` emits per-view modular
frames, the Pallas SF matched pair runs them on-kernel (frames scalar-
prefetched per view), and the iterative solvers work out of the box because
the backprojector is the exact transpose of the forward.

    PYTHONPATH=src python examples/helical_recon.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (Projector, ProjectorSpec, VolumeGeometry, from_config,
                        helical_beam)
from repro.data.metrics import psnr
from repro.recon import cgls, fista_tv, sirt

vol = VolumeGeometry(32, 32, 16)
geom = helical_beam(n_turns=2.0, pitch=8.0, n_angles=48, n_rows=12,
                    n_cols=48, vol=vol, sod=130.0, sdd=260.0,
                    pixel_width=2.0, pixel_height=2.0)
src = np.asarray(geom.source_pos)
print(f"helical scan: {geom.n_angles} views over 2 turns, "
      f"source z {src[0, 2]:.1f} -> {src[-1, 2]:.1f} mm "
      f"(pitch 8 mm/turn)")

# the same scan is expressible as a config file (from_config round-trip)
cfg = {"geom_type": "helical", "n_turns": 2.0, "pitch": 8.0,
       "n_angles": 48, "n_rows": 12, "n_cols": 48, "sod": 130.0,
       "sdd": 260.0, "pixel_width": 2.0, "pixel_height": 2.0,
       "volume": {"nx": 32, "ny": 32, "nz": 16}}
assert from_config(cfg).canonical_hash() == geom.canonical_hash()

# synthetic object spanning the full z extent (what the helix exists for)
f = jnp.zeros(vol.shape).at[9:17, 9:20, 2:14].set(0.02)
f = f.at[20:27, 7:13, 5:11].set(0.035)
f = f.at[13:19, 21:27, 9:15].set(0.027)

proj = Projector(ProjectorSpec(geom, model="sf"))  # modular SF matched pair
y = proj(f)
print(f"sinogram {y.shape}, projector {proj}")

x_sirt = sirt(proj, y, n_iters=30).image
x_cgls = cgls(proj, y, n_iters=20).image
x_tv = fista_tv(proj, y, n_iters=30, beta=2e-3).image
print(f"helical SIRT     PSNR {psnr(x_sirt, f, 0.035):.2f} dB")
print(f"helical CGLS     PSNR {psnr(x_cgls, f, 0.035):.2f} dB")
print(f"helical FISTA-TV PSNR {psnr(x_tv, f, 0.035):.2f} dB")

"""Cone-beam + modular-geometry iterative reconstruction with matched pairs:
CGLS and FISTA-TV on a 3D cone-beam scan, then the same object scanned with
an arbitrary (modular) source/detector trajectory.

    PYTHONPATH=src python examples/iterative_recon.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (Projector, ProjectorSpec, VolumeGeometry, cone_beam,
                        modular_beam)
from repro.data.metrics import psnr
from repro.recon import cgls, fista_tv

vol = VolumeGeometry(48, 48, 16)
geom = cone_beam(n_angles=60, n_rows=32, n_cols=72, vol=vol,
                 sod=200.0, sdd=400.0, pixel_width=2.0, pixel_height=2.0)
proj = Projector(ProjectorSpec(geom, model="sf"))

# synthetic object: two blocks
f = jnp.zeros(vol.shape).at[14:26, 14:30, 4:12].set(0.02)
f = f.at[30:40, 10:20, 6:10].set(0.035)
y = proj(f)
y_noisy = y + 0.01 * float(jnp.abs(y).max()) * jax.random.normal(
    jax.random.PRNGKey(0), y.shape)

x_cgls = cgls(proj, y_noisy, n_iters=25).image
x_tv = fista_tv(proj, y_noisy, n_iters=40, beta=2e-3).image
print(f"cone-beam CGLS     PSNR {psnr(x_cgls, f, 0.035):.2f} dB")
print(f"cone-beam FISTA-TV PSNR {psnr(x_tv, f, 0.035):.2f} dB")

# --- modular geometry: a non-circular trajectory (two tilted arcs) --------
ang = np.linspace(0, 2 * np.pi, 40, endpoint=False)
tilt = 0.15 * np.sin(2 * ang)
src = np.stack([200 * np.cos(ang), 200 * np.sin(ang), 40 * tilt], -1)
ctr = -src * (200.0 / 200.0)
eu = np.stack([-np.sin(ang), np.cos(ang), np.zeros_like(ang)], -1)
ev = np.cross(src / np.linalg.norm(src, axis=1, keepdims=True), eu)
geom_mod = modular_beam(src, ctr, eu, ev, n_rows=32, n_cols=72, vol=vol,
                        pixel_width=2.0, pixel_height=2.0)
proj_mod = Projector(ProjectorSpec(geom_mod))  # Joseph ray-marching path
y_mod = proj_mod(f)
x_mod = cgls(proj_mod, y_mod, n_iters=25).image
print(f"modular   CGLS     PSNR {psnr(x_mod, f, 0.035):.2f} dB")

"""End-to-end driver: the paper's §4 limited-angle experiment.

Trains the hybrid CT-Net (sinogram completion) + U-Net (image refinement)
model on randomized ellipse phantoms with the differentiable projector
providing (a) on-the-fly ill-posed input generation, (b) the
data-consistency loss during training, and (c) the iterative refinement at
inference — all three usage modes from the paper.

    PYTHONPATH=src python examples/train_limited_angle.py \
        --steps 300 --size 64 --ckpt-dir /tmp/ct_ckpt
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Projector, VolumeGeometry, parallel_beam
from repro.data.metrics import psnr, ssim
from repro.data.pipeline import CTDataPipeline
from repro.nn.ctnet import ctnet_apply, ctnet_init
from repro.nn.unet import unet_apply, unet_init
from repro.optim import adamw, apply_updates, warmup_cosine
from repro.recon import complete_and_refine
from repro.runtime import checkpoint as CKPT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--available-deg", type=float, default=60.0)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--dc-weight", type=float, default=0.1)
    args = ap.parse_args()

    n = args.size
    vol = VolumeGeometry(n, n, 1)
    geom = parallel_beam(int(1.5 * n), 1, int(1.5 * n), vol)
    proj = Projector(geom, "sf")
    pipe = CTDataPipeline(geom, batch_size=args.batch, seed=0,
                          available_deg=args.available_deg)

    key = jax.random.PRNGKey(0)
    params = {"ctnet": ctnet_init(key, base=16, depth=3),
              "unet": unet_init(jax.random.fold_in(key, 1), base=16, levels=2)}
    opt = adamw(warmup_cosine(2e-3, 20, args.steps))
    state = opt.init(params)

    def predict(p, sino_masked, mask2d):
        completed = ctnet_apply(p["ctnet"], sino_masked, mask2d)  # (B,na,nu)
        x_in = proj.fbp(completed[:, :, None, :])                 # (B,nx,ny,1)
        pred = unet_apply(p["unet"], x_in[..., 0][..., None])[..., 0]
        return pred, completed

    def loss_fn(p, sino, mask, gt):
        mask2d = mask[:, :, None] * jnp.ones((1, 1, geom.n_cols))
        pred, completed = predict(p, sino[:, :, 0, :] * mask2d, mask2d)
        rec = jnp.mean((pred - gt) ** 2)
        sino_l = jnp.mean((completed - sino[:, :, 0, :]) ** 2)
        dc = jnp.mean(jnp.square(
            (proj(pred[..., None]) - sino) * mask[:, :, None, None]))
        return rec + 0.5 * sino_l + args.dc_weight * dc

    @jax.jit
    def step(p, s, sino, mask, gt):
        l, g = jax.value_and_grad(loss_fn)(p, sino, mask, gt)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    start = 0
    if args.ckpt_dir and CKPT.latest_step(args.ckpt_dir) is not None:
        (params, state), extra, start = CKPT.restore(args.ckpt_dir,
                                                     (params, state))
        pipe.load_state_dict(extra["data"])
        print(f"resumed from step {start}")
    ckpt = CKPT.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    for i in range(start, args.steps):
        imgs, masks = pipe.batch(i)
        gt = jnp.asarray(imgs)
        sino = proj(gt[..., None])
        params, state, l = step(params, state, sino, jnp.asarray(masks), gt)
        if i % 20 == 0:
            print(f"step {i:4d}  loss {float(l):.5f}  "
                  f"({(time.time()-t0)/max(i-start+1,1):.2f}s/step)")
        if ckpt and (i + 1) % 50 == 0:
            ckpt.save(i + 1, (params, state), {"data": pipe.state_dict()})
    if ckpt:
        ckpt.save(args.steps, (params, state), {"data": pipe.state_dict()})
        ckpt.wait()

    # ---- inference with sinogram completion + DC refinement (paper Fig. 3)
    p_net, p_ref, s_net, s_ref = [], [], [], []
    for k in range(4):
        img, mask = pipe.sample(10_000 + k, 0)
        gt = jnp.asarray(img)
        sino = proj(gt[..., None])
        mask2d = jnp.asarray(mask)[:, None] * jnp.ones((1, geom.n_cols))
        pred, _ = predict(params, sino[None, :, 0, :] * mask2d[None], mask2d[None])
        pred = pred[0]
        xr, _ = complete_and_refine(proj, pred[..., None], sino,
                                    jnp.asarray(mask)[:, None, None],
                                    n_iters=20, beta=0.05)
        peak = float(gt.max())
        p_net.append(psnr(pred, gt, peak)); s_net.append(ssim(np.asarray(pred), np.asarray(gt), peak))
        p_ref.append(psnr(np.asarray(xr)[..., 0], gt, peak))
        s_ref.append(ssim(np.asarray(xr)[..., 0], np.asarray(gt), peak))
    print(f"\nheld-out ({args.available_deg:.0f}deg of 180):")
    print(f"  network prediction : PSNR {np.mean(p_net):6.3f} dB  SSIM {np.mean(s_net):.4f}")
    print(f"  + data consistency : PSNR {np.mean(p_ref):6.3f} dB  SSIM {np.mean(s_ref):.4f}")
    print("(the paper reports 35.486/0.905 -> 36.350/0.911 on luggage CT)")


if __name__ == "__main__":
    main()

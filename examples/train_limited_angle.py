"""End-to-end driver: the paper's §4 limited-angle experiment.

Thin CLI over the :mod:`repro.launch.ct_train` subsystem — the hybrid
CT-Net (sinogram completion) + U-Net (image refinement) model trained with
the differentiable projector providing (a) on-the-fly ill-posed input
generation, (b) the data-consistency loss during training, and (c) the
iterative refinement at inference — all three usage modes from the paper.
The ad-hoc training loop this file used to carry lives in
``CTTrainer.fit()`` now (same losses, plus EMA eval params, atomic
checkpoint/resume, and optional data-parallel sharding).

    PYTHONPATH=src python examples/train_limited_angle.py \
        --steps 300 --size 64 --ckpt-dir /tmp/ct_ckpt
"""
import argparse

from repro.launch.ct_train import CTTrainer, TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--size", type=int, default=48)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--available-deg", type=float, default=60.0)
    ap.add_argument("--ckpt-dir", type=str, default=None)
    ap.add_argument("--dc-weight", type=float, default=0.1)
    ap.add_argument("--compute-dtype", type=str, default=None)
    args = ap.parse_args()

    cfg = TrainConfig(geometry="limited_angle", model="hybrid",
                      n=args.size, steps=args.steps, batch=args.batch,
                      available_deg=args.available_deg,
                      dc_weight=args.dc_weight, ckpt_dir=args.ckpt_dir,
                      compute_dtype=args.compute_dtype)
    trainer = CTTrainer(cfg)
    trainer.fit()

    # ---- inference with sinogram completion + DC refinement (paper Fig. 3)
    m = trainer.evaluate(n_test=4)
    print(f"\nheld-out ({args.available_deg:.0f}deg of 180):")
    print(f"  network prediction : PSNR {m['psnr_net']:6.3f} dB  "
          f"SSIM {m['ssim_net']:.4f}")
    print(f"  + data consistency : PSNR {m['psnr_refined']:6.3f} dB  "
          f"SSIM {m['ssim_refined']:.4f}")
    print(f"  projection residual: {m['dc_net']:.4f} -> "
          f"{m['dc_refined']:.4f}")
    print("(the paper reports 35.486/0.905 -> 36.350/0.911 on luggage CT)")


if __name__ == "__main__":
    main()

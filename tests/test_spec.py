"""ProjectorSpec: validation, content identity, cache keys, legacy shims,
and the stable-geometry-hash bugfix."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Projector, ProjectorSpec, VolumeGeometry, cone_beam,
                        fan_beam, from_config, helical_beam, modular_beam,
                        parallel_beam)
from repro.core.spec import ShardSpec, as_spec, reset_legacy_warnings
from repro.kernels import ops
from repro.kernels.tune import KernelConfig


@pytest.fixture()
def geom():
    return parallel_beam(12, 1, 16, VolumeGeometry(8, 8, 1))


def _geoms():
    vol = VolumeGeometry(8, 8, 4)
    ang = np.linspace(0, 2 * np.pi, 6, endpoint=False)
    src = np.stack([100 * np.cos(ang), 100 * np.sin(ang),
                    np.zeros_like(ang)], -1)
    eu = np.stack([-np.sin(ang), np.cos(ang), np.zeros_like(ang)], -1)
    ev = np.tile(np.array([0.0, 0.0, 1.0]), (len(ang), 1))
    return {
        "parallel": parallel_beam(12, 1, 16, VolumeGeometry(8, 8, 1)),
        "fan": fan_beam(12, 1, 16, VolumeGeometry(8, 8, 1), sod=50.0,
                        sdd=100.0),
        "cone": cone_beam(6, 4, 16, vol, sod=50.0, sdd=100.0),
        "modular": modular_beam(src, -src, eu, ev, n_rows=4, n_cols=16,
                                vol=vol),
        "helical": helical_beam(1.5, 4.0, 12, 4, 16, vol, sod=50.0,
                                sdd=100.0),
    }


# -- construction / validation ---------------------------------------------- #
def test_spec_validates_eagerly(geom):
    with pytest.raises(ValueError, match="model"):
        ProjectorSpec(geom, model="nope")
    with pytest.raises(ValueError, match="backend"):
        ProjectorSpec(geom, backend="gpu")
    with pytest.raises(ValueError, match="mode"):
        ProjectorSpec(geom, mode="lazy")
    with pytest.raises(ValueError):
        ProjectorSpec(geom, compute_dtype="float16")
    with pytest.raises(TypeError, match="KernelConfig"):
        ProjectorSpec(geom, config={"bu": 8})
    with pytest.raises(TypeError, match="CTGeometry"):
        ProjectorSpec("not a geometry")


def test_spec_canonicalizes_dtype_aliases(geom):
    assert ProjectorSpec(geom, compute_dtype="bf16").compute_dtype == "bfloat16"
    assert (ProjectorSpec(geom, compute_dtype="bf16")
            == ProjectorSpec(geom, compute_dtype="bfloat16"))


# -- content identity -------------------------------------------------------- #
def test_spec_equality_is_content_based():
    vol = VolumeGeometry(8, 8, 1)
    a = ProjectorSpec(parallel_beam(12, 1, 16, vol))
    b = ProjectorSpec(parallel_beam(12, 1, 16, VolumeGeometry(8, 8, 1)))
    assert a == b and hash(a) == hash(b)
    assert a != ProjectorSpec(parallel_beam(12, 1, 16, vol), model="joseph")
    assert a.bucket_key() == b.bucket_key()
    assert a.replace(mode="exact") != a


def test_spec_hashable_in_sets(geom):
    s = {ProjectorSpec(geom), ProjectorSpec(geom),
         ProjectorSpec(geom, model="joseph")}
    assert len(s) == 2


def test_config_participates_in_identity(geom):
    pinned = ProjectorSpec(geom, config=KernelConfig(bu=8))
    assert pinned != ProjectorSpec(geom)
    assert pinned.bucket_key() != ProjectorSpec(geom).bucket_key()


# -- stable geometry hashing (the bugfix) ------------------------------------ #
def test_geometry_hash_float_repr_stable():
    vol = VolumeGeometry(8, 8, 1)
    a = fan_beam(12, 1, 16, vol, sod=50.0, sdd=100.0)
    b = fan_beam(12, 1, 16, vol, sod=np.float32(50.0), sdd=np.float64(100.0))
    assert a.canonical_hash() == b.canonical_hash()
    assert a.key() == b.key()


@pytest.mark.parametrize("kind", ["parallel", "fan", "cone", "modular",
                                  "helical"])
def test_to_config_roundtrip_hash(kind):
    g = _geoms()[kind]
    g2 = from_config(g.to_config())
    assert g2.canonical_hash() == g.canonical_hash()
    assert ProjectorSpec(g) == ProjectorSpec(g2)


def test_modular_frames_hashed_by_content():
    g = _geoms()["modular"]
    cfg = g.to_config()
    g2 = from_config(cfg)
    assert g2.canonical_hash() == g.canonical_hash()
    cfg_moved = dict(cfg)
    src = np.asarray(cfg["source_pos"], float).copy()
    src[0, 0] += 1.0
    cfg_moved["source_pos"] = src.tolist()
    assert from_config(cfg_moved).canonical_hash() != g.canonical_hash()


# -- op-cache key unification ------------------------------------------------ #
def test_spec_and_legacy_share_op_cache(geom):
    ops.clear_cache()
    spec = ProjectorSpec(geom)
    fp_spec, bp_spec = ops.get_ops(spec)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fp_legacy, bp_legacy = ops.get_ops(geom)
    assert fp_spec is fp_legacy and bp_spec is bp_legacy
    assert ops.cache_stats()["size"] == 1


def test_equal_specs_share_cached_bundle(geom):
    ops.clear_cache()
    f = jnp.ones(geom.vol.shape)
    a = ops.forward_project(f, ProjectorSpec(geom))
    size1 = ops.cache_stats()["size"]
    b = ops.forward_project(f, ProjectorSpec(
        parallel_beam(12, 1, 16, VolumeGeometry(8, 8, 1))))
    assert ops.cache_stats()["size"] == size1
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


# -- legacy shims ------------------------------------------------------------ #
def test_legacy_kwargs_warn_exactly_once(geom):
    reset_legacy_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        p1 = Projector(geom, model="sf")
        p2 = Projector(geom, model="joseph")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "ProjectorSpec" in str(dep[0].message)
    # distinct entry points warn independently, still once each
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ops.forward_project(jnp.ones(geom.vol.shape), geom)
        ops.forward_project(jnp.ones(geom.vol.shape), geom)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert p1.spec.model == "sf" and p2.spec.model == "joseph"


def test_legacy_behavior_preserved(geom):
    f = jnp.ones(geom.vol.shape)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = Projector(geom, "sf", mode="exact")
        y_fn = ops.forward_project(f, geom, mode="exact")
    spec = ProjectorSpec(geom, model="sf", mode="exact")
    modern = Projector(spec)
    assert legacy.spec == spec
    np.testing.assert_allclose(np.asarray(legacy(f)), np.asarray(modern(f)),
                               rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(y_fn), np.asarray(modern(f)),
                               rtol=0, atol=0)


def test_spec_plus_kwargs_is_an_error(geom):
    spec = ProjectorSpec(geom)
    with pytest.raises(TypeError, match="not both"):
        Projector(spec, model="joseph")
    with pytest.raises(TypeError, match="not both"):
        as_spec(spec, "get_ops", mode="packed")
    with pytest.raises(TypeError, match="ProjectorSpec or CTGeometry"):
        as_spec(42, "get_ops")


def test_projector_backcompat_attributes(geom):
    proj = Projector(ProjectorSpec(geom, compute_dtype="bf16"))
    assert proj.geom is geom
    assert proj.model == "sf" and proj.backend == "auto"
    assert proj.mode == "auto" and proj.compute_dtype == "bfloat16"
    assert proj.config is None


# -- ShardSpec ---------------------------------------------------------------- #
def test_shard_spec_validation():
    with pytest.raises(ValueError, match="mesh_axes"):
        ShardSpec(mesh_axes=("data",))
    with pytest.raises(ValueError, match="angle axis"):
        ShardSpec(mesh_axes=(None, "model"))
    with pytest.raises(ValueError, match="distinct"):
        ShardSpec(mesh_axes=("data", "data"))
    with pytest.raises(ValueError, match=">= 1"):
        ShardSpec(angle_shards=0)
    with pytest.raises(ValueError, match="z mesh axis"):
        ShardSpec(mesh_axes=("data", None), z_shards=2)
    with pytest.raises(ValueError, match="halo"):
        ShardSpec(halo=-1)
    with pytest.raises(ValueError, match="meaningless"):
        ShardSpec(z_shards=1, halo=2)
    with pytest.raises(ValueError, match="comm"):
        ShardSpec(comm="ring")
    with pytest.raises(ValueError, match="comm_blocks"):
        ShardSpec(comm_blocks=-1)


def test_shard_spec_hash_roundtrip():
    a = ShardSpec(("data", "model"), angle_shards=4, z_shards=2, halo=3)
    b = ShardSpec(("data", "model"), angle_shards=4, z_shards=2, halo=3)
    assert a == b and hash(a) == hash(b)
    assert a.replace(halo=2) != a
    assert a.angle_axis == "data" and a.z_axis == "model"
    # round-trips through its own field dict (config-file currency)
    import dataclasses
    c = ShardSpec(**dataclasses.asdict(a))
    assert c == a and hash(c) == hash(a)
    assert len({a, b, a.replace(comm="psum")}) == 2


def test_shard_participates_in_spec_identity(geom):
    shard = ShardSpec(("data", "model"), angle_shards=2, z_shards=2, halo=1)
    plain = ProjectorSpec(geom)
    sharded = ProjectorSpec(geom, shard=shard)
    assert plain != sharded and hash(plain) != hash(sharded)
    assert plain.bucket_key() != sharded.bucket_key()
    assert plain.cache_key() != sharded.cache_key()
    # same layout content -> same identity, regardless of object
    again = ProjectorSpec(geom, shard=ShardSpec(("data", "model"),
                                                angle_shards=2, z_shards=2,
                                                halo=1))
    assert sharded == again and hash(sharded) == hash(again)
    assert sharded.bucket_key() == again.bucket_key()
    # different layouts must not share serving buckets or cache entries
    other = ProjectorSpec(geom, shard=shard.replace(comm="psum"))
    assert other != sharded and other.bucket_key() != sharded.bucket_key()
    with pytest.raises(TypeError, match="ShardSpec"):
        ProjectorSpec(geom, shard="angle")
    assert "shard=" in repr(sharded)

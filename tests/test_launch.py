"""Launcher integration: train loop + checkpoint resume + failure injection,
and the dry-run cell machinery on the local mesh (CI-scale)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat, configs
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop
from repro.runtime import checkpoint as CKPT


def test_train_loop_checkpoint_resume(tmp_path):
    cfg = dataclasses.replace(configs.get_smoke("tinyllama_1_1b"),
                              grad_accum=1)
    mesh = make_local_mesh()
    # run 1: 6 steps, checkpoint every 3
    pipe = TokenPipeline(cfg.vocab_size, 32, 4)
    _, losses_a = train_loop(cfg, mesh, pipe, steps=6,
                             ckpt_dir=str(tmp_path), ckpt_every=3,
                             log_every=100)
    assert CKPT.latest_step(str(tmp_path)) == 6
    # run 2 from scratch to 3, then resume 3->6: the resumed loss trajectory
    # must match run 1 exactly (deterministic data + exact state restore)
    d2 = tmp_path / "two"
    pipe2 = TokenPipeline(cfg.vocab_size, 32, 4)
    train_loop(cfg, mesh, pipe2, steps=3, ckpt_dir=str(d2), ckpt_every=3,
               log_every=100)
    pipe3 = TokenPipeline(cfg.vocab_size, 32, 4)
    _, losses_b = train_loop(cfg, mesh, pipe3, steps=6, ckpt_dir=str(d2),
                             ckpt_every=3, log_every=100)
    np.testing.assert_allclose(losses_a[3:], losses_b, rtol=1e-4)


@pytest.mark.slow
def test_supervisor_recovers_from_injected_failure(tmp_path):
    """Full driver subprocess: crash at step 10, auto-restart, finish."""
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-0.6b",
           "--smoke", "--steps", "16", "--batch", "4", "--seq", "32",
           "--ckpt-dir", str(tmp_path), "--fail-at", "10", "--ckpt-every", "4"]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done." in out.stdout
    assert CKPT.latest_step(str(tmp_path)) == 16


def test_dryrun_cell_machinery_local():
    """lower_cell logic on a 1-device mesh with a reduced config — validates
    the sharding/lowering plumbing the 512-device dry-run uses."""
    from repro.launch import sharding
    from repro.launch.steps import make_serve_step, make_train_step
    from repro.models import model as MD
    from repro.optim import adamw, constant

    cfg = dataclasses.replace(configs.get_smoke("qwen3_0_6b"), grad_accum=1)
    mesh = make_local_mesh()
    ac = sharding.make_ac(mesh, cfg)
    aparams = MD.abstract_params(cfg)
    pshard = sharding.param_shardings(cfg, aparams, mesh)
    opt = adamw(constant(1e-3))
    aopt = jax.eval_shape(opt.init, aparams)
    step = make_train_step(cfg, opt, ac)
    batch = {"tokens": jax.ShapeDtypeStruct((4, 64), jnp.int32)}
    with mesh:
        lowered = jax.jit(step, in_shardings=(pshard, None, None)).lower(
            aparams, aopt, batch)
        compiled = lowered.compile()
    assert compat.cost_analysis_dict(compiled).get("flops", 0) > 0
    # decode path
    serve = make_serve_step(cfg, ac)
    cache = MD.cache_shapes(cfg, 4, 64)
    cshard = sharding.cache_shardings(cache, mesh)
    with mesh:
        lowered = jax.jit(serve, in_shardings=(pshard, cshard, None, None)) \
            .lower(aparams, cache,
                   jax.ShapeDtypeStruct((4,), jnp.int32),
                   jax.ShapeDtypeStruct((), jnp.int32))
        lowered.compile()


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.roofline import collective_bytes_from_hlo
    hlo = """
ENTRY %main (a: f32[8,128]) -> f32[8,128] {
  %x = f32[8,128]{1,0} all-reduce(f32[8,128] %a), replica_groups={{0,1,2,3}}
  %y = bf16[4,256]{1,0} all-gather(bf16[4,64] %b), replica_groups=[2,8]
  ROOT %z = f32[8,128]{1,0} collective-permute(f32[8,128] %x)
}
"""
    out = collective_bytes_from_hlo(hlo, n_devices=8)
    assert out["op_counts"] == {"all-reduce": 1, "all-gather": 1,
                                "collective-permute": 1}
    # all-reduce: 8*128*4 bytes * 2*(4-1)/4
    assert abs(out["per_op_bytes"]["all-reduce"] - 8 * 128 * 4 * 1.5) < 1
    # all-gather: result 4*256*2 bytes * (8-1)/8
    assert abs(out["per_op_bytes"]["all-gather"] - 4 * 256 * 2 * 7 / 8) < 1


@pytest.mark.slow
def test_elastic_remesh_plan_compiles(tmp_path):
    """Lose a pod's worth of chips -> plan_remesh shrinks the data axis ->
    the SAME training program lowers+compiles on the surviving mesh.
    (Subprocess: needs its own forced host device count.)

    The plan logic is asserted at full scale (160 chips -> (8, 16)); the
    compile proof runs a *smaller* remesh scenario (40 chips -> (2, 16), 32
    forced host devices) with the layer count shrunk via cfg_overrides —
    the 128-device full-model compile exceeded the subprocess timeout on
    2-vCPU CI-class containers (see CHANGES.md PR 4), and neither the mesh
    logic nor the sharding validity depends on the layer count."""
    from repro.runtime.fault import plan_remesh
    assert plan_remesh(n_healthy_chips=160, model_axis=16, pods=1) == (8, 16)
    new_shape = plan_remesh(n_healthy_chips=40, model_axis=16, pods=1)
    assert new_shape == (2, 16)       # 32 of the surviving 40 chips
    script = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"
import jax
# Initialize the backend *before* importing dryrun: its module-level
# XLA_FLAGS write forces 512 host devices for the CLI use case, and a
# 512-device CPU client is most of what made this test time out.
assert jax.device_count() == 32
from repro.launch.dryrun import lower_cell
mesh = jax.make_mesh({new_shape!r}, ("data", "model"))
lowered, reason = lower_cell("qwen3-0.6b", "train_4k", mesh,
                             cfg_overrides={{"n_layers": 2}})
assert reason is None
lowered.compile()
print("REMESH_OK")
"""
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=900,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert out.returncode == 0, out.stderr[-2000:]
    assert "REMESH_OK" in out.stdout

"""Fan-beam geometry end to end: Pallas kernels vs the jnp oracle, matched
adjoints, fan FBP weighting (cosine / equiangular ramp correction / Parker
short-scan), and reconstruction quality vs the parallel-beam baseline."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Projector, VolumeGeometry, fan_beam, parallel_beam
from repro.core.fbp import parker_weights
from repro.kernels import ops, ref
from repro.kernels.fp_fan import bp_fan_sf_pallas, fp_fan_sf_pallas
from repro.kernels.tune import KernelConfig
from repro.data.phantoms import shepp_logan_2d

RTOL = ATOL = 2e-4


def _assert_close(a, b, tol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


def _psnr(rec, f):
    mse = float(jnp.mean((rec - f) ** 2))
    return 10 * np.log10(float(jnp.max(f)) ** 2 / mse)


# --------------------------------------------------------------------------- #
# Kernels vs oracle
# --------------------------------------------------------------------------- #
FAN_SHAPES = [
    # nx, ny, nz, na, nv, nu, sod, sdd, detector_type
    (16, 16, 4, 6, 4, 24, 80.0, 160.0, "flat"),
    (24, 24, 2, 5, 2, 36, 120.0, 200.0, "curved"),   # non-tile-multiple dims
]


@pytest.mark.parametrize("shape", FAN_SHAPES)
def test_fan_fp_bp_match_oracle(shape):
    nx, ny, nz, na, nv, nu, sod, sdd, det = shape
    g = fan_beam(na, nv, nu, VolumeGeometry(nx, ny, nz), sod=sod, sdd=sdd,
                 pixel_width=2.0, detector_type=det)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(fp_fan_sf_pallas(f, g), ref.forward(f, g, "sf"))
    _assert_close(bp_fan_sf_pallas(y, g), ref.adjoint(y, g, "sf"))


def test_fan_view_blocking_matches_oracle():
    """ba/bab > 1 (view-blocked fan FP/BP) is exactly the unblocked math."""
    g = fan_beam(7, 3, 28, VolumeGeometry(16, 16, 3), sod=60.0, sdd=120.0,
                 pixel_width=2.0, detector_type="curved")
    cfg = KernelConfig(bu=8, ba=3, bg=8, bab=2)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(fp_fan_sf_pallas(f, g, config=cfg), ref.forward(f, g, "sf"))
    _assert_close(bp_fan_sf_pallas(y, g, config=cfg), ref.adjoint(y, g, "sf"))


@pytest.mark.parametrize("det", ["flat", "curved"])
def test_fan_windowed_gather_matches_oracle(det):
    """Geometry sized so the static window bounds do NOT clamp to the full
    axis (W < ng in FP, Wu < nup in BP): exercises the window-start
    inversion — incl. the curved-detector tan inversion — that full-axis
    shapes skip.  Guarded by assertions on the actual window sizes."""
    from repro.kernels import fp_fan
    g = fan_beam(4, 1, 128, VolumeGeometry(48, 48, 1), sod=200.0, sdd=220.0,
                 pixel_width=1.0, detector_type=det)
    cfg = KernelConfig(bu=8, bg=8)
    assert fp_fan._window_size_fan(g, cfg.bu, g.vol.nx) < g.vol.nx
    assert fp_fan._u_window_size_div(g, cfg.bg, g.n_cols) < g.n_cols
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(fp_fan_sf_pallas(f, g, config=cfg), ref.forward(f, g, "sf"))
    _assert_close(bp_fan_sf_pallas(y, g, config=cfg), ref.adjoint(y, g, "sf"))


def test_fan_registered_dispatch():
    assert ("fan", "sf") in ops._KERNEL_TABLE
    g = fan_beam(6, 2, 24, VolumeGeometry(16, 16, 2), sod=60.0, sdd=120.0,
                 pixel_width=2.0)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    out = ops.forward_project(f, g, "sf", backend="pallas")
    _assert_close(out, ref.forward(f, g, "sf"))


def test_fan_parallel_limit():
    """sod -> inf with the pixel width scaled by the magnification reduces
    the fan transform to the parallel one."""
    v = VolumeGeometry(24, 24, 2)
    gp = parallel_beam(8, 2, 36, v, angular_range=360.0)
    gf = fan_beam(8, 2, 36, v, sod=1e5, sdd=2e5, pixel_width=2.0,
                  angular_range=360.0)
    f = jax.random.uniform(jax.random.PRNGKey(0), v.shape)
    pf, pp = ref.forward(f, gf, "sf"), ref.forward(f, gp, "sf")
    err = float(jnp.abs(pf - pp).max() / jnp.abs(pp).max())
    assert err < 1e-3, err


# --------------------------------------------------------------------------- #
# FBP weighting
# --------------------------------------------------------------------------- #
def test_fan_fbp_quantitative_disc():
    """Uniform disc reconstructs to its density in 1/mm (both detectors)."""
    vol = VolumeGeometry(64, 64, 2)
    xs = vol.x_coords()
    X, Y = np.meshgrid(xs, vol.y_coords(), indexing="ij")
    fd = (0.02 * ((X ** 2 + Y ** 2) <= 12.0 ** 2)).astype(np.float32)
    fd = jnp.asarray(np.repeat(fd[:, :, None], 2, axis=2))
    for det in ("flat", "curved"):
        g = fan_beam(180, 2, 112, vol, sod=180.0, sdd=360.0, pixel_width=2.0,
                     angular_range=360.0, detector_type=det)
        proj = Projector(g, "sf")
        rec = proj.fbp(proj(fd))
        center = np.asarray(rec[28:36, 28:36, 1]).mean()
        assert abs(center / 0.02 - 1.0) < 0.05, (det, center)


def test_fan_fbp_psnr_matches_parallel_baseline():
    """Shepp-Logan via fan FBP lands within 1 dB of the parallel-beam FBP
    baseline on an equivalent full-scan geometry (acceptance criterion)."""
    vol = VolumeGeometry(64, 64, 1)
    f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
    gp = parallel_beam(90, 1, 96, vol)
    pp = Projector(gp, "sf")
    base = _psnr(pp.fbp(pp(f)), f)
    for det in ("flat", "curved"):
        gf = fan_beam(360, 1, 96, vol, sod=200.0, sdd=400.0, pixel_width=2.0,
                      angular_range=360.0, detector_type=det)
        pf = Projector(gf, "sf")
        got = _psnr(pf.fbp(pf(f)), f)
        assert got > base - 1.0, (det, got, base)


def test_fan_parker_short_scan():
    """Parker weighting makes a pi + 2*delta short scan usable: a large PSNR
    gain over naive (double-counted) weighting on the same data."""
    vol = VolumeGeometry(64, 64, 1)
    f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
    gamma_max = math.atan((95 / 2 * 2.0) / 400.0)
    rng_deg = math.degrees(math.pi + 2 * gamma_max)
    g = fan_beam(144, 1, 96, vol, sod=200.0, sdd=400.0, pixel_width=2.0,
                 angular_range=rng_deg)
    proj = Projector(g, "sf")
    sino = proj(f)
    parker = _psnr(proj.fbp(sino), f)               # auto-detects short scan
    naive = _psnr(proj.fbp(sino, short_scan=False), f)
    assert parker > 20.0, parker
    assert parker > naive + 4.0, (parker, naive)


def test_parker_weights_conjugate_sum():
    """Parker weights of a conjugate ray pair — (beta, gamma) and
    (beta + pi - 2*gamma, -gamma) — sum to ~1: the redundancy split that
    replaces the full-scan 1/2."""
    g = fan_beam(200, 1, 64, VolumeGeometry(32, 32, 1), sod=100.0, sdd=200.0,
                 pixel_width=1.0,
                 angular_range=math.degrees(math.pi + 2 * math.atan(31.5 / 200)))
    w = parker_weights(g)
    assert w.shape == (200, 64)
    assert w.min() >= 0.0 and w.max() <= 1.0
    gamma = np.arctan2(g.u_coords(), g.sdd)
    ang = np.asarray(g.angles_array())
    iu = 20                                # -gamma lives at the mirror column
    iu_m = g.n_cols - 1 - iu
    conj = ang + np.pi - 2 * gamma[iu]
    inside = np.nonzero((conj >= ang.min()) & (conj <= ang.max()))[0]
    ic = np.clip(np.searchsorted(ang, conj[inside]), 0, len(ang) - 1)
    s = w[inside, iu] + w[ic, iu_m]
    assert np.all(np.abs(s - 1.0) < 0.08), (s.min(), s.max())

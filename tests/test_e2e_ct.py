"""End-to-end reproduction of the paper's §4 experiment (reduced scale):
limited-angle CT -> U-Net prediction -> sinogram completion + iterative
data-consistency refinement must improve PSNR over the raw prediction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Projector, VolumeGeometry, parallel_beam
from repro.data.pipeline import CTDataPipeline
from repro.nn.unet import unet_apply, unet_init
from repro.optim import adamw, apply_updates, constant
from repro.recon import complete_and_refine


def psnr(a, b, peak):
    mse = float(jnp.mean((a - b) ** 2))
    return 10 * np.log10(peak ** 2 / max(mse, 1e-20))


@pytest.fixture(scope="module")
def trained():
    vol = VolumeGeometry(32, 32, 1)
    geom = parallel_beam(48, 1, 48, vol)
    proj = Projector(geom, "sf")
    pipe = CTDataPipeline(geom, batch_size=4, seed=0, mode="limited_angle",
                          available_deg=60.0)
    params = unet_init(jax.random.PRNGKey(0), base=8, levels=2)
    opt = adamw(constant(1e-3))
    state = opt.init(params)

    def loss_fn(p, x_in, x_gt, sino, mask):
        pred = unet_apply(p, x_in[..., None])[..., 0]
        rec_loss = jnp.mean((pred - x_gt) ** 2)
        # the paper's data-consistency term through the differentiable A
        dc = jnp.mean(jnp.square((proj(pred[..., None]) - sino) * mask))
        return rec_loss + 0.1 * dc

    step = jax.jit(lambda p, s, a, b, c, d: _step(p, s, a, b, c, d))

    def _step(p, s, x_in, x_gt, sino, mask):
        l, g = jax.value_and_grad(loss_fn)(p, x_in, x_gt, sino, mask)
        u, s = opt.update(g, s, p)
        return apply_updates(p, u), s, l

    data = []
    for i in range(4):
        imgs, masks = pipe.batch(i)
        gt = jnp.asarray(imgs)
        sino = proj(gt[..., None])
        mvec = jnp.asarray(masks)[:, :, None, None]
        x_in = proj.fbp(sino * mvec)[..., 0]
        data.append((x_in, gt, sino, mvec))
    losses = []
    for i in range(80):
        a, b, c, d = data[i % 4]
        params, state, l = step(params, state, a, b, c, d)
        losses.append(float(l))
    return proj, pipe, params, losses


def test_training_converges(trained):
    _, _, _, losses = trained
    assert np.mean(losses[-8:]) < 0.6 * np.mean(losses[:4]), losses[::16]


def test_data_consistency_refinement_improves_psnr(trained):
    proj, pipe, params, _ = trained
    # held-out sample
    img, mask = pipe.sample(10_000, 0)
    gt = jnp.asarray(img)
    sino = proj(gt[..., None])
    mvec = jnp.asarray(mask)[:, None, None]
    x_in = proj.fbp(sino * mvec)[..., 0]
    pred = unet_apply(params, x_in[None, ..., None])[0, ..., 0]
    peak = float(gt.max())
    p_fbp = psnr(x_in, gt, peak)
    p_net = psnr(pred, gt, peak)
    x_ref, completed = complete_and_refine(proj, pred[..., None], sino, mvec,
                                           n_iters=20, beta=0.05)
    p_ref = psnr(x_ref[..., 0], gt, peak)
    # net beats raw limited-angle FBP; refinement beats the net (paper Fig. 3)
    assert p_net > p_fbp, (p_fbp, p_net)
    assert p_ref > p_net, (p_net, p_ref)
    # measured views preserved exactly in the completed sinogram
    keep = np.asarray(mask) > 0
    np.testing.assert_allclose(np.asarray(completed)[keep],
                               np.asarray(sino)[keep], rtol=0, atol=0)


def test_gradients_flow_through_projector(trained):
    proj, pipe, params, _ = trained
    img, mask = pipe.sample(11_000, 0)
    gt = jnp.asarray(img)
    sino = proj(gt[..., None])
    mvec = jnp.asarray(mask)[:, None, None]
    x_in = proj.fbp(sino * mvec)[..., 0]

    def dc_loss(p):
        pred = unet_apply(p, x_in[None, ..., None])[0, ..., 0]
        return jnp.mean(jnp.square((proj(pred[..., None]) - sino) * mvec))

    g = jax.grad(dc_loss)(params)
    total = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0

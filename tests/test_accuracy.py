"""Quantitative accuracy (paper: 'all numerical values scale appropriately').

* analytic ellipse line integrals vs SF/Joseph projections
* exact mass conservation of the SF footprint
* mm-scaling invariance: scaling voxel+pixel sizes by s scales projections by s
* quantitative FBP/FDK: uniform disc reconstructs to its density in 1/mm
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Projector, VolumeGeometry, cone_beam, parallel_beam
from repro.data.phantoms import (Ellipse, analytic_parallel_projection,
                                 rasterize, shepp_logan_2d)


def _phantom_geom(n=64, na=24, supersample=4):
    vol = VolumeGeometry(n, n, 1)
    g = parallel_beam(na, 1, int(1.5 * n), vol)
    ells = [Ellipse(5.0, -3.0, 18.0, 11.0, 0.4, 0.8),
            Ellipse(-8.0, 6.0, 7.0, 12.0, -0.2, 0.5)]
    img = rasterize(ells, vol, supersample)
    return g, ells, jnp.asarray(img[:, :, None])


@pytest.mark.parametrize("model", ["sf", "joseph"])
def test_analytic_ellipse_projection(model):
    g, ells, f = _phantom_geom()
    sino = Projector(g, model)(f)[:, 0, :]
    ana = analytic_parallel_projection(ells, np.asarray(g.angles),
                                       g.u_coords())
    err = np.abs(np.asarray(sino) - ana)
    # discretized phantom vs analytic: few-percent sup-norm, sub-percent L1
    assert err.max() / ana.max() < 0.12
    assert err.mean() / ana.mean() < 0.02


def test_sf_mass_conservation():
    """Sum over detector of SF projection x du == integral of the slice —
    exact (to fp32) by construction of the trapezoid footprint."""
    vol = VolumeGeometry(32, 32, 4)
    g = parallel_beam(16, 4, 64, vol)
    f = jax.random.uniform(jax.random.PRNGKey(0), vol.shape)
    sino = Projector(g, "sf")(f)
    mass_p = np.asarray(sino[:, 1, :].sum(axis=1)) * g.pixel_width
    mass_f = float(f[:, :, 1].sum()) * vol.dx * vol.dy
    np.testing.assert_allclose(mass_p, mass_f, rtol=1e-5)


@pytest.mark.parametrize("model", ["sf", "joseph"])
def test_mm_scaling(model):
    """Scaling all geometry lengths by s scales line integrals by s."""
    s = 2.5
    vol1 = VolumeGeometry(24, 24, 4)
    g1 = parallel_beam(8, 4, 36, vol1)
    vol2 = vol1.scale(s)
    g2 = dataclasses.replace(g1, vol=vol2, pixel_width=g1.pixel_width * s,
                             pixel_height=g1.pixel_height * s)
    f = jax.random.uniform(jax.random.PRNGKey(1), vol1.shape)
    p1 = Projector(g1, model)(f)
    p2 = Projector(g2, model)(f)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p1) * s,
                               rtol=1e-4, atol=1e-5)


def test_fbp_quantitative_parallel():
    vol = VolumeGeometry(96, 96, 2)
    g = parallel_beam(120, 2, 144, vol)
    xs = vol.x_coords()
    X, Y = np.meshgrid(xs, vol.y_coords(), indexing="ij")
    f = (0.02 * ((X ** 2 + Y ** 2) <= 15.0 ** 2)).astype(np.float32)
    f = jnp.asarray(np.repeat(f[:, :, None], 2, axis=2))
    proj = Projector(g, "sf")
    rec = proj.fbp(proj(f))
    center = np.asarray(rec[42:54, 42:54, 1]).mean()
    assert abs(center / 0.02 - 1.0) < 0.02


def test_fdk_quantitative_cone():
    vol = VolumeGeometry(96, 96, 4)
    g = cone_beam(240, 16, 160, vol, sod=250.0, sdd=500.0,
                  pixel_width=2.0, pixel_height=2.0)
    xs = vol.x_coords()
    X, Y = np.meshgrid(xs, vol.y_coords(), indexing="ij")
    f = (0.02 * ((X ** 2 + Y ** 2) <= 15.0 ** 2)).astype(np.float32)
    f = jnp.asarray(np.repeat(f[:, :, None], 4, axis=2))
    proj = Projector(g, "sf")
    rec = proj.fbp(proj(f))
    center = np.asarray(rec[42:54, 42:54, 2]).mean()
    assert abs(center / 0.02 - 1.0) < 0.05


def test_shepp_logan_roundtrip_psnr():
    vol = VolumeGeometry(64, 64, 1)
    g = parallel_beam(90, 1, 96, vol)
    f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
    proj = Projector(g, "sf")
    rec = proj.fbp(proj(f))
    mse = float(jnp.mean((rec - f) ** 2))
    psnr = 10 * np.log10(float(jnp.max(f)) ** 2 / mse)
    assert psnr > 24.0, psnr

"""Distributed CT projection (shard_map over angles / z-slabs).

Single-device CI runs the (1, 1)-mesh paths (shard_map wiring, psum,
ppermute self-loops, validation, the legacy shim) plus everything that is
pure host code (``suggest_halo``).  The multi-shard numerics — halo
exchange vs a numpy oracle, the three sharded layouts vs local ops,
adjointness, the sliding-z helical capacity proof — run under the CI
``distributed`` leg with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
and are skip-gated on device count here."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import (Projector, ProjectorSpec, ShardSpec, VolumeGeometry,
                        cone_beam, helical_beam, parallel_beam)
from repro.core.distributed import (DistributedProjector, _angle_chunks,
                                    distribute, halo_exchange_z,
                                    halo_reduce_z, make_distributed_projector,
                                    suggest_halo)
from repro.kernels import ops
from repro.recon.result import as_projector
from repro.recon.sirt import sirt

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.fixture(scope="module")
def mesh42():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return jax.make_mesh((4, 2), ("data", "model"))


@pytest.fixture(scope="module")
def mesh24():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices")
    return jax.make_mesh((2, 4), ("data", "model"))


def _dot_rel(dp, geom, seed=0):
    """Conditioning-aware adjointness error: |<Ax,y> - <x,A^T y>| over the
    term mass sum|Ax*y| (a raw /|<Ax,y>| blows up when the random dot
    cancels)."""
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, geom.vol.shape)
    y = jax.random.normal(ky, geom.sino_shape)
    Ax = dp(dp.shard_volume(x))
    ATy = dp.T(dp.shard_sino(y))
    lhs = jnp.vdot(Ax, y)
    rhs = jnp.vdot(x, ATy)
    mass = float(jnp.sum(jnp.abs(Ax * y))) + 1e-12
    return abs(float(lhs - rhs)) / mass


def _vs_local(dp, geom, tol=2e-5, seed=0):
    fp, bp = ops.get_ops(dp.spec.replace(shard=None))
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, geom.vol.shape)
    y = jax.random.normal(ky, geom.sino_shape)
    np.testing.assert_allclose(np.asarray(dp(dp.shard_volume(x))),
                               np.asarray(fp(x)), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(dp.T(dp.shard_sino(y))),
                               np.asarray(bp(y)), rtol=tol,
                               atol=tol * float(jnp.max(jnp.abs(bp(y)))))


# --------------------------------------------------------------------------- #
# Single-device paths (tier-1)
# --------------------------------------------------------------------------- #
def test_legacy_factory_matches_local(mesh):
    vol = VolumeGeometry(24, 24, 4)
    g = parallel_beam(8, 4, 36, vol)
    fp, bp, shard_v, shard_s = make_distributed_projector(
        g, mesh, angle_axis="data", z_axis="model")
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    proj = Projector(ProjectorSpec(g))
    np.testing.assert_allclose(np.asarray(fp(shard_v(f))),
                               np.asarray(proj(f)), rtol=1e-5, atol=1e-5)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    np.testing.assert_allclose(np.asarray(bp(shard_s(y))),
                               np.asarray(proj.T(y)), rtol=1e-5, atol=1e-5)
    # the spec_vol/spec_sino attribute-stuffing hack is gone
    assert not hasattr(fp, "spec_vol") and not hasattr(fp, "spec_sino")


def test_legacy_factory_warns_once(mesh):
    from repro.core.spec import reset_legacy_warnings
    reset_legacy_warnings()
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    with pytest.warns(DeprecationWarning):
        make_distributed_projector(g, mesh)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        make_distributed_projector(g, mesh)   # second call: silent


def test_legacy_factory_cone_zslab_still_not_implemented(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = cone_beam(4, 4, 24, vol, sod=60.0, sdd=80.0)
    with pytest.raises(NotImplementedError, match="halo"):
        make_distributed_projector(g, mesh, z_axis="model")


def test_distributed_pair_matched(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    dp = distribute(ProjectorSpec(g), mesh, z_axis="model")
    assert _dot_rel(dp, g) < 1e-6


def test_angle_chunking_requires_divisibility():
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(5, 4, 24, vol)
    with pytest.raises(ValueError, match="divisible"):
        _angle_chunks(g, 2)


def test_halo_exchange_validates_halo_width():
    f = jnp.zeros((4, 4, 4))
    with pytest.raises(ValueError, match="smaller than the local slab"):
        halo_exchange_z(f, "model", 4)
    with pytest.raises(ValueError, match=">= 0"):
        halo_exchange_z(f, "model", -1)
    with pytest.raises(ValueError, match="extended slab"):
        halo_reduce_z(f, "model", 2)


def test_halo_exchange_identity_on_single_shard(mesh):
    f = jax.random.normal(jax.random.PRNGKey(0), (8, 8, 6))

    @partial(compat.shard_map, mesh=mesh,
             in_specs=(P(None, None, "model"),),
             out_specs=P(None, None, "model"), check_vma=False)
    def run(fl):
        return halo_exchange_z(fl, "model", 2)

    out = run(f)
    # single shard: both halos are fleet edges -> zeros
    assert out.shape == (8, 8, 10)
    np.testing.assert_array_equal(np.asarray(out[:, :, :2]), 0.0)
    np.testing.assert_allclose(np.asarray(out[:, :, 2:8]), np.asarray(f))
    np.testing.assert_array_equal(np.asarray(out[:, :, 8:]), 0.0)


def test_ops_cache_rejects_sharded_spec():
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    spec = ProjectorSpec(g, shard=ShardSpec(("data", None)))
    with pytest.raises(ValueError, match="DistributedProjector"):
        ops.get_ops(spec)


def test_as_projector_accepts_distributed(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    dp = distribute(ProjectorSpec(g), mesh)
    assert as_projector(dp) is dp
    with pytest.raises(ValueError, match="mesh"):
        as_projector(ProjectorSpec(g, shard=ShardSpec(("data", None))))


def test_distributed_projector_validation(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    with pytest.raises(TypeError, match="ProjectorSpec"):
        DistributedProjector(g, mesh)
    with pytest.raises(ValueError, match="ShardSpec"):
        DistributedProjector(ProjectorSpec(g), mesh)
    # shard layout must match the mesh
    spec = ProjectorSpec(g, shard=ShardSpec(("data", None), angle_shards=4))
    with pytest.raises(ValueError, match="mesh axis"):
        DistributedProjector(spec, mesh)
    spec = ProjectorSpec(g, shard=ShardSpec(("rows", None)))
    with pytest.raises(ValueError, match="no axis"):
        DistributedProjector(spec, mesh)
    with pytest.raises(TypeError, match="not both"):
        distribute(ProjectorSpec(g, shard=ShardSpec(("data", None))),
                   mesh, z_axis="model")


def test_suggest_halo():
    vol = VolumeGeometry(24, 24, 8)
    # parallel/fan: slabs exactly independent
    assert suggest_halo(parallel_beam(8, 8, 36, vol), 2) == 0
    gc = cone_beam(8, 8, 36, vol, sod=60.0, sdd=80.0)
    h = suggest_halo(gc, 2)
    assert 1 <= h < 4          # small cone angle: a sliver, not a slab
    tall = VolumeGeometry(24, 24, 32)
    gh = helical_beam(n_turns=4, pitch=8.0, n_angles=32, n_rows=6, n_cols=32,
                      vol=tall, sod=60.0, sdd=80.0)
    h = suggest_halo(gh, 4)
    assert 1 <= h < 8          # halo < nz_local: the pipeline is feasible
    with pytest.raises(ValueError, match="divisible"):
        suggest_halo(gh, 3)
    assert suggest_halo(gh, 1) == 0


def test_sirt_bit_parity_single_device_mesh(mesh):
    # On a (1,1) mesh with the synchronous-psum schedule the distributed
    # program runs the *same* cached local ops — sirt must be bit-exact
    # against the plain Projector run.
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(8, 4, 24, vol)
    spec = ProjectorSpec(g)
    dp = distribute(spec, mesh, comm="psum")
    f = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), vol.shape))
    y = Projector(spec)(f)
    a = sirt(dp, y, n_iters=4)
    b = sirt(spec, y, n_iters=4)
    np.testing.assert_array_equal(np.asarray(a.image), np.asarray(b.image))
    np.testing.assert_array_equal(np.asarray(a.residual_history),
                                  np.asarray(b.residual_history))


# --------------------------------------------------------------------------- #
# Multi-shard numerics (CI `distributed` leg: 8 forced host devices)
# --------------------------------------------------------------------------- #
@needs8
def test_halo_exchange_matches_numpy_oracle(mesh24):
    nz, shards, halo = 16, 4, 2
    nzl = nz // shards
    f = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (6, 6, nz)))

    @partial(compat.shard_map, mesh=mesh24,
             in_specs=(P(None, None, "model"),),
             out_specs=P(None, None, "model"), check_vma=False)
    def run(fl):
        return halo_exchange_z(fl, "model", halo)

    out = np.asarray(run(jnp.asarray(f)))
    assert out.shape == (6, 6, shards * (nzl + 2 * halo))
    padded = np.concatenate([np.zeros((6, 6, halo)), f,
                             np.zeros((6, 6, halo))], axis=2)
    for k in range(shards):
        got = out[:, :, k * (nzl + 2 * halo):(k + 1) * (nzl + 2 * halo)]
        want = padded[:, :, k * nzl:k * nzl + nzl + 2 * halo]
        np.testing.assert_allclose(got, want, err_msg=f"shard {k}")


@needs8
def test_halo_reduce_is_adjoint_of_exchange(mesh24):
    nz, halo = 16, 2
    ext = nz + 2 * halo * 4
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 5, nz))
    y = jax.random.normal(jax.random.PRNGKey(1), (5, 5, ext))

    @partial(compat.shard_map, mesh=mesh24,
             in_specs=(P(None, None, "model"),),
             out_specs=P(None, None, "model"), check_vma=False)
    def E(fl):
        return halo_exchange_z(fl, "model", halo)

    @partial(compat.shard_map, mesh=mesh24,
             in_specs=(P(None, None, "model"),),
             out_specs=P(None, None, "model"), check_vma=False)
    def ET(gl):
        return halo_reduce_z(gl, "model", halo)

    lhs = float(jnp.vdot(E(x), y))
    rhs = float(jnp.vdot(x, ET(y)))
    assert abs(lhs - rhs) / (abs(lhs) + 1e-12) < 1e-5


@needs8
def test_angle_sharded_matches_local_and_adjoint(mesh42):
    vol = VolumeGeometry(24, 24, 8)
    g = parallel_beam(16, 8, 32, vol)
    dp = distribute(ProjectorSpec(g), mesh42)
    _vs_local(dp, g)
    assert _dot_rel(dp, g) < 1e-6


@needs8
def test_parallel_zslab_matches_local_and_adjoint(mesh42):
    vol = VolumeGeometry(24, 24, 8)
    g = parallel_beam(16, 8, 32, vol)
    dp = distribute(ProjectorSpec(g), mesh42, z_axis="model")
    assert dp.shard.halo == 0
    _vs_local(dp, g)
    assert _dot_rel(dp, g) < 1e-6


@needs8
def test_cone_halo_zslab_matches_local_and_adjoint(mesh42):
    vol = VolumeGeometry(24, 24, 8)
    g = cone_beam(16, 8, 32, vol, sod=60.0, sdd=80.0)
    dp = distribute(ProjectorSpec(g), mesh42, z_axis="model")
    assert dp.shard.halo >= 1          # halo path actually exercised
    _vs_local(dp, g)
    assert _dot_rel(dp, g) < 1e-6


@needs8
def test_cone_undersized_halo_rejected(mesh42):
    vol = VolumeGeometry(24, 24, 8)
    g = cone_beam(16, 8, 32, vol, sod=60.0, sdd=80.0)
    with pytest.raises(ValueError, match="halo"):
        distribute(ProjectorSpec(g), mesh42, z_axis="model", halo=0)


@needs8
def test_helical_sliding_z_capacity_and_adjoint(mesh24):
    # The long-object proof: with z_shards=4 each device materializes an
    # (nzl + 2*halo)-deep slab that is strictly smaller than the full
    # volume — a volume that exceeds one device's slab budget reconstructs
    # anyway.
    tall = VolumeGeometry(24, 24, 32)
    g = helical_beam(n_turns=4, pitch=8.0, n_angles=32, n_rows=6, n_cols=32,
                     vol=tall, sod=60.0, sdd=80.0)
    dp = distribute(ProjectorSpec(g), mesh24, z_axis="model")
    nzl = tall.nz // dp.shard.z_shards
    assert nzl + 2 * dp.shard.halo < tall.nz
    _vs_local(dp, g)
    assert _dot_rel(dp, g) < 1e-6


@needs8
def test_helical_sliding_z_sirt_end_to_end(mesh24):
    tall = VolumeGeometry(24, 24, 32)
    g = helical_beam(n_turns=4, pitch=8.0, n_angles=32, n_rows=6, n_cols=32,
                     vol=tall, sod=60.0, sdd=80.0)
    spec = ProjectorSpec(g)
    dp = distribute(spec, mesh24, z_axis="model")
    f = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), tall.shape))
    y = dp(dp.shard_volume(f))
    res = sirt(dp, y, n_iters=12)
    hist = np.asarray(res.residual_history)
    assert hist[-1] < 0.25 * hist[0]   # the mesh loop actually converges
    # parity with the single-device solve
    ref = sirt(spec, y, n_iters=12)
    np.testing.assert_allclose(np.asarray(res.image), np.asarray(ref.image),
                               rtol=1e-4, atol=1e-4)


@needs8
def test_overlap_comm_matches_psum(mesh24):
    tall = VolumeGeometry(24, 24, 32)
    g = helical_beam(n_turns=4, pitch=8.0, n_angles=32, n_rows=6, n_cols=32,
                     vol=tall, sod=60.0, sdd=80.0)
    spec = ProjectorSpec(g)
    over = distribute(spec, mesh24, z_axis="model", comm="overlap")
    sync = distribute(spec, mesh24, z_axis="model", comm="psum")
    y = jax.random.normal(jax.random.PRNGKey(0), g.sino_shape)
    np.testing.assert_allclose(np.asarray(over.T(over.shard_sino(y))),
                               np.asarray(sync.T(sync.shard_sino(y))),
                               rtol=1e-5, atol=1e-5)


@needs8
def test_dp_train_step_decreases_loss(mesh42):
    from repro.launch.train import make_ct_dp_train_step
    vol = VolumeGeometry(16, 16, 8)
    g = parallel_beam(16, 8, 24, vol)
    spec = ProjectorSpec(g)

    def apply_fn(params, y):
        return jnp.broadcast_to(params["vol"], (y.shape[0],) + vol.shape)

    step = make_ct_dp_train_step(spec, mesh42, apply_fn, lr=5e-3,
                                 axis="data")
    truth = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), vol.shape))
    y1 = Projector(spec)(truth)
    yb = jnp.stack([y1] * 8)
    params = {"vol": jnp.zeros(vol.shape)}
    losses = []
    for _ in range(5):
        params, loss = step(params, yb)
        losses.append(float(loss))
    # mechanics test, not a convergence benchmark: grads flow through the
    # matched pair, the pmean syncs shards, and every step improves
    assert all(b < a for a, b in zip(losses, losses[1:]))
    assert losses[-1] < losses[0]

"""Distributed CT projection (shard_map over angles / z-slabs).

With one real device the mesh is (1, 1) — the shard_map code path, psum and
ppermute wiring all execute; multi-shard numeric equality is additionally
exercised by forcing a 1x1 'grid' vs the single-device op."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import Projector, VolumeGeometry, parallel_beam
from repro.core.distributed import halo_exchange_z, make_distributed_projector


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_distributed_matches_local(mesh):
    vol = VolumeGeometry(24, 24, 4)
    g = parallel_beam(8, 4, 36, vol)
    fp, bp, shard_v, shard_s = make_distributed_projector(
        g, mesh, angle_axis="data", z_axis="model")
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    proj = Projector(g, "sf")
    np.testing.assert_allclose(np.asarray(fp(shard_v(f))),
                               np.asarray(proj(f)), rtol=1e-5, atol=1e-5)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    np.testing.assert_allclose(np.asarray(bp(shard_s(y))),
                               np.asarray(proj.T(y)), rtol=1e-5, atol=1e-5)


def test_distributed_pair_matched(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(4, 4, 24, vol)
    fp, bp, shard_v, shard_s = make_distributed_projector(
        g, mesh, angle_axis="data", z_axis="model")
    x = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    lhs = jnp.vdot(fp(shard_v(x)), y)
    rhs = jnp.vdot(x, bp(shard_s(y)))
    assert abs(lhs - rhs) / abs(lhs) < 2e-5


def test_angle_chunking_requires_divisibility(mesh):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(5, 4, 24, vol)
    jax.make_mesh((1, 1), ("data", "model"))
    # n_angles=5 divides 1, fine; simulate failure via manual check
    from repro.core.distributed import _angle_chunks
    with pytest.raises(AssertionError):
        _angle_chunks(g, 2)


def test_halo_exchange_identity_on_single_shard(mesh):
    f = jax.random.normal(jax.random.PRNGKey(0), (8, 8, 6))

    from functools import partial
    @partial(compat.shard_map, mesh=mesh,
             in_specs=(jax.sharding.PartitionSpec(None, None, "model"),),
             out_specs=jax.sharding.PartitionSpec(None, None, "model"),
             check_vma=False)
    def run(fl):
        return halo_exchange_z(fl, "model", 2)

    out = run(f)
    # single shard: both halos are fleet edges -> zeros
    assert out.shape == (8, 8, 10)
    np.testing.assert_array_equal(np.asarray(out[:, :, :2]), 0.0)
    np.testing.assert_allclose(np.asarray(out[:, :, 2:8]), np.asarray(f))
    np.testing.assert_array_equal(np.asarray(out[:, :, 8:]), 0.0)

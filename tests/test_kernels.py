"""Pallas kernel vs pure-jnp oracle: allclose across shape/dtype sweeps
(interpret mode on CPU; identical code path compiles for TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; the non-property "
    "kernel-vs-oracle coverage lives in tests/test_batched_pallas.py")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.geometry import VolumeGeometry, parallel_beam
from repro.kernels import ref
from repro.kernels.fp_par import bp_parallel_sf_pallas, fp_parallel_sf_pallas

SHAPES = [
    (16, 16, 4, 6, 4, 24),     # nx, ny, nz, na, nv, nu
    (32, 32, 8, 12, 8, 48),
    (24, 24, 2, 5, 2, 40),     # non-multiple-of-tile sizes
    (32, 32, 8, 9, 8, 33),
]


@pytest.mark.parametrize("shape", SHAPES)
def test_fp_matches_oracle(shape):
    nx, ny, nz, na, nv, nu = shape
    vol = VolumeGeometry(nx, ny, nz)
    g = parallel_beam(na, nv, nu, vol)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    p_ref = ref.forward(f, g, "sf")
    p_pal = fp_parallel_sf_pallas(f, g)
    np.testing.assert_allclose(np.asarray(p_pal), np.asarray(p_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", SHAPES[:2])
def test_bp_matches_oracle(shape):
    nx, ny, nz, na, nv, nu = shape
    vol = VolumeGeometry(nx, ny, nz)
    g = parallel_beam(na, nv, nu, vol)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    b_ref = ref.adjoint(y, g, "sf")
    b_pal = bp_parallel_sf_pallas(y, g)
    np.testing.assert_allclose(np.asarray(b_pal), np.asarray(b_ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 0.05)])
def test_fp_dtypes(dtype, tol):
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(6, 4, 24, vol)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape).astype(dtype)
    p_ref = ref.forward(f.astype(jnp.float32), g, "sf")
    p_pal = fp_parallel_sf_pallas(f, g).astype(jnp.float32)
    err = float(jnp.abs(p_pal - p_ref).max())
    assert err <= tol * float(jnp.abs(p_ref).max()), err


def test_fp_anisotropic_pixels():
    vol = VolumeGeometry(20, 20, 4, dx=1.5, dy=1.5, dz=2.0)
    g = parallel_beam(8, 6, 30, vol, pixel_width=1.1, pixel_height=1.3)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    np.testing.assert_allclose(np.asarray(fp_parallel_sf_pallas(f, g)),
                               np.asarray(ref.forward(f, g, "sf")),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(na=st.integers(2, 10), seed=st.integers(0, 1000),
       du=st.floats(0.7, 1.6))
def test_fp_property_random_geoms(na, seed, du):
    rng = np.random.default_rng(seed)
    vol = VolumeGeometry(16, 16, 2)
    ang = np.sort(rng.uniform(0, np.pi, na))
    g = parallel_beam(na, 2, 28, vol, angles=ang, pixel_width=du)
    f = jnp.asarray(rng.normal(size=vol.shape).astype(np.float32))
    np.testing.assert_allclose(np.asarray(fp_parallel_sf_pallas(f, g)),
                               np.asarray(ref.forward(f, g, "sf")),
                               rtol=3e-4, atol=3e-4)


def test_kernel_registered_dispatch():
    from repro.kernels import ops
    assert ("parallel", "sf") in ops._KERNEL_TABLE
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(6, 4, 24, vol)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    # explicit pallas backend routes through the kernel
    out = ops.forward_project(f, g, "sf", backend="pallas")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.forward(f, g, "sf")),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# Cone-beam SF kernel
# --------------------------------------------------------------------------- #
CONE_SHAPES = [
    # nx, ny, nz, na, nv, nu, sod, sdd
    (16, 16, 8, 6, 8, 24, 80.0, 160.0),
    (24, 24, 4, 5, 8, 36, 120.0, 200.0),    # non-tile-multiple views/rows
    (16, 16, 16, 4, 16, 24, 60.0, 150.0),   # taller stack, higher mag
]


@pytest.mark.parametrize("shape", CONE_SHAPES)
def test_fp_cone_matches_oracle(shape):
    from repro.core.geometry import cone_beam
    from repro.kernels.fp_cone import fp_cone_sf_pallas
    nx, ny, nz, na, nv, nu, sod, sdd = shape
    vol = VolumeGeometry(nx, ny, nz)
    g = cone_beam(na, nv, nu, vol, sod=sod, sdd=sdd,
                  pixel_width=2.0, pixel_height=2.0)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    p_ref = ref.forward(f, g, "sf")
    p_pal = fp_cone_sf_pallas(f, g, bu=8, bv=8)
    np.testing.assert_allclose(np.asarray(p_pal), np.asarray(p_ref),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("shape", CONE_SHAPES)
def test_bp_cone_matches_oracle(shape):
    """The Pallas cone BP (exact transpose of the forward kernel) against
    the jnp-oracle adjoint."""
    from repro.core.geometry import cone_beam
    from repro.kernels.fp_cone import bp_cone_sf_pallas
    nx, ny, nz, na, nv, nu, sod, sdd = shape
    vol = VolumeGeometry(nx, ny, nz)
    g = cone_beam(na, nv, nu, vol, sod=sod, sdd=sdd,
                  pixel_width=2.0, pixel_height=2.0)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    b_ref = ref.adjoint(y, g, "sf")
    b_pal = bp_cone_sf_pallas(y, g, bg=8, bv=8)
    np.testing.assert_allclose(np.asarray(b_pal), np.asarray(b_ref),
                               rtol=3e-4, atol=3e-4)


def test_bp_cone_view_blocked_matches_oracle():
    """bab > 1 / non-multiple bg (padded views and gathered tiles) is
    exactly the unblocked math."""
    from repro.core.geometry import cone_beam
    from repro.kernels.fp_cone import bp_cone_sf_pallas
    from repro.kernels.tune import KernelConfig
    vol = VolumeGeometry(16, 16, 8)
    g = cone_beam(5, 8, 24, vol, sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    b_ref = ref.adjoint(y, g, "sf")
    b_pal = bp_cone_sf_pallas(y, g, config=KernelConfig(bg=12, bv=8, bab=2))
    np.testing.assert_allclose(np.asarray(b_pal), np.asarray(b_ref),
                               rtol=3e-4, atol=3e-4)


def test_cone_pallas_pair_matched():
    """Registered cone pair (Pallas fwd + Pallas BP, the matched pair) —
    the BP is the exact transpose of the forward kernel."""
    from repro.core.geometry import cone_beam
    from repro.core import Projector
    vol = VolumeGeometry(16, 16, 8)
    g = cone_beam(6, 8, 24, vol, sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / abs(lhs) < 1e-4


# --------------------------------------------------------------------------- #
# Mixed precision (bf16-tile / f32-accumulate) property sweep
# --------------------------------------------------------------------------- #
@settings(max_examples=8, deadline=None)
@given(na=st.integers(3, 10), nu=st.integers(20, 40),
       du=st.floats(0.7, 1.6), seed=st.integers(0, 1000))
def test_bf16_fp_error_bound_property(na, nu, du, seed):
    """Across randomized parallel geometries, the bf16-tile FP stays within
    the documented BF16_FP_REL_BOUND of the f32 oracle while measurably
    differing from the f32 kernel run (the cast actually happened)."""
    from repro.kernels import precision
    rng = np.random.default_rng(seed)
    vol = VolumeGeometry(16, 16, 4)
    ang = np.sort(rng.uniform(0, np.pi, na))
    g = parallel_beam(na, 4, nu, vol, angles=ang, pixel_width=du)
    f = jnp.asarray(rng.normal(size=vol.shape).astype(np.float32))
    s_ref = ref.forward(f, g, "sf")
    s_b = fp_parallel_sf_pallas(f, g, compute_dtype="bfloat16")
    assert s_b.dtype == jnp.float32
    denom = float(jnp.abs(s_ref).max())
    rel = float(jnp.abs(s_b - s_ref).max()) / max(denom, 1e-9)
    assert rel < precision.BF16_FP_REL_BOUND, rel


@settings(max_examples=6, deadline=None)
@given(bs=st.integers(1, 4), bg=st.sampled_from([8, 16]),
       seed=st.integers(0, 1000))
def test_bp_stripe_reuse_exact_property(bs, bg, seed):
    """BP stripe blocking (bs) is a pure re-blocking: any (bg, bs) combo
    reproduces the oracle adjoint to f32 tolerance."""
    rng = np.random.default_rng(seed)
    vol = VolumeGeometry(16, 16, 4)
    g = parallel_beam(6, 4, 24, vol)
    y = jnp.asarray(rng.normal(size=g.sino_shape).astype(np.float32))
    b_ref = ref.adjoint(y, g, "sf")
    b_pal = bp_parallel_sf_pallas(y, g, bg=bg, bs=bs)
    np.testing.assert_allclose(np.asarray(b_pal), np.asarray(b_ref),
                               rtol=2e-4, atol=2e-4)

"""Pallas flash-attention kernel vs oracle: shape/dtype/GQA/window sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash import flash_attention, flash_ref


def _qkv(B, H, KV, S, hd, dtype=jnp.float32, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,KV,S,hd,bq,bk", [
    (1, 4, 2, 128, 32, 32, 32),     # GQA 2:1
    (2, 2, 2, 256, 16, 64, 128),    # MHA, rectangular blocks
    (1, 8, 1, 128, 64, 64, 32),     # MQA
])
def test_flash_matches_oracle(B, H, KV, S, hd, bq, bk):
    q, k, v = _qkv(B, H, KV, S, hd)
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, bq=bq, bk=bk)),
        np.asarray(flash_ref(q, k, v)), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [32, 64, 96])
def test_flash_sliding_window(window):
    q, k, v = _qkv(1, 4, 2, 256, 32)
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, window=window, bq=32, bk=32)),
        np.asarray(flash_ref(q, k, v, window=window)), rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(1, 2, 2, 128, 32, dtype=jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64).astype(jnp.float32)
    ref = flash_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32))
    assert float(jnp.abs(out - ref).max()) < 0.05


def test_flash_matches_model_attention():
    """The kernel agrees with the model's chunked jnp attention path."""
    from repro.models.layers import _flash_attention as jnp_flash
    B, H, KV, S, hd = 1, 4, 2, 256, 32
    q4, k4, v4 = _qkv(B, H, KV, S, hd, key=7)
    # model layout: (B, S, H, hd)
    o_jnp = jnp_flash(q4.transpose(0, 2, 1, 3), k4.transpose(0, 2, 1, 3),
                      v4.transpose(0, 2, 1, 3), None, None, 64, 64)
    o_pal = flash_attention(q4, k4, v4, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(o_pal.transpose(0, 2, 1, 3)),
                               np.asarray(o_jnp), rtol=3e-5, atol=3e-5)


def test_flash_backward_matches_autodiff_oracle():
    """custom_vjp backward (FlashAttention-2 two-kernel form, block-skipped)
    vs jax.grad through the dense oracle."""
    from repro.kernels.flash import flash_attention_diff
    B, H, KV, S, hd = 1, 4, 2, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, KV, S, hd))
    v = jax.random.normal(ks[2], (B, KV, S, hd))
    do = jax.random.normal(ks[3], (B, H, S, hd))
    for window in (None, 48):
        g1 = jax.grad(lambda *a: jnp.sum(
            flash_attention_diff(*a, window, 32, 32) * do), (0, 1, 2))(q, k, v)
        g2 = jax.grad(lambda *a: jnp.sum(
            flash_ref(*a, window) * do), (0, 1, 2))(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=3e-5, atol=3e-5)


def test_flash_diff_forward_consistent():
    from repro.kernels.flash import flash_attention_diff
    q, k, v = _qkv(1, 2, 1, 128, 16, key=9)
    np.testing.assert_allclose(
        np.asarray(flash_attention_diff(q, k, v, None, 64, 64)),
        np.asarray(flash_ref(q, k, v)), rtol=2e-5, atol=2e-5)

"""CT serving subsystem: bucketing, packed dispatch, tiers, warm path,
and per-request error isolation."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Projector, ProjectorSpec, VolumeGeometry, fan_beam,
                        parallel_beam)
from repro.kernels import ops, tune
from repro.launch.ct_serve import (CTServer, ReconRequest, TIER_SOLVERS,
                                   solver_tier, _size_class)
from repro.recon import sirt


@pytest.fixture(scope="module")
def world():
    vol = VolumeGeometry(16, 16, 1)
    g_par = parallel_beam(12, 1, 24, vol)
    g_fan = fan_beam(12, 1, 24, vol, sod=60.0, sdd=120.0)
    s_par, s_fan = ProjectorSpec(g_par), ProjectorSpec(g_fan)
    f = jnp.zeros(vol.shape).at[5:11, 5:11, :].set(0.02)
    return {"f": f, "par": (s_par, Projector(s_par)(f)),
            "fan": (s_fan, Projector(s_fan)(f))}


def test_solver_tiers():
    assert solver_tier("fbp") == "interactive"
    for s in TIER_SOLVERS["quality"]:
        assert solver_tier(s) == "quality"
    with pytest.raises(ValueError):
        solver_tier("mystery")


def test_size_classes():
    assert [_size_class(n, 16) for n in (1, 2, 3, 5, 16, 40)] == \
        [1, 2, 4, 8, 16, 16]
    assert _size_class(7, 4) == 4


def test_batched_matches_per_request(world):
    """A packed batch answers bit-identically to what the solver produces
    on each request alone."""
    spec, y = world["par"]
    srv = CTServer(max_batch=8)
    rids = [srv.submit(ReconRequest(spec=spec, sino=(i + 1) * y,
                                    solver="sirt",
                                    solver_kwargs={"n_iters": 5}))
            for i in range(5)]
    done = srv.drain()
    assert len(srv.dispatch_log) == 1
    rec = srv.dispatch_log[0]
    assert rec["size_class"] == 8 and sorted(rec["rids"]) == sorted(rids)
    for i, rid in enumerate(rids):
        resp = done[rid]
        assert resp.ok and resp.batch_size == 5
        direct = sirt(spec, (i + 1) * y, n_iters=5)
        np.testing.assert_allclose(np.asarray(resp.image),
                                   np.asarray(direct.image),
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(np.asarray(resp.result.residual_history),
                                   np.asarray(direct.residual_history),
                                   rtol=1e-5, atol=1e-7)


def test_heterogeneous_specs_never_share_a_batch(world):
    """Requests with different geometry content — or the same geometry but
    different solver kwargs — must land in separate packed dispatches."""
    (s_par, y_par), (s_fan, y_fan) = world["par"], world["fan"]
    srv = CTServer(max_batch=16)
    kinds = {}
    for i in range(12):
        if i % 3 == 0:
            r = ReconRequest(spec=s_par, sino=y_par, solver="fbp")
        elif i % 3 == 1:
            r = ReconRequest(spec=s_fan, sino=y_fan, solver="fbp")
        else:
            r = ReconRequest(spec=s_par, sino=y_par, solver="fbp",
                             solver_kwargs={"filter_name": "hann"})
        kinds[srv.submit(r)] = i % 3
    done = srv.drain()
    assert all(done[r].ok for r in kinds)
    assert len(srv.dispatch_log) == 3
    for rec in srv.dispatch_log:
        assert len({kinds[r] for r in rec["rids"]}) == 1, \
            "heterogeneous requests packed into one batch"


def test_tier_priority(world):
    """Interactive requests are dispatched before quality requests even
    when the quality queue is older."""
    spec, y = world["par"]
    srv = CTServer(max_batch=8)
    q = srv.submit(ReconRequest(spec=spec, sino=y, solver="sirt",
                                solver_kwargs={"n_iters": 3}))
    i = srv.submit(ReconRequest(spec=spec, sino=y, solver="fbp"))
    done = srv.drain()
    assert done[q].ok and done[i].ok
    assert [rec["tier"] for rec in srv.dispatch_log] == \
        ["interactive", "quality"]


def test_submit_validation_is_isolated(world):
    spec, y = world["par"]
    srv = CTServer(max_batch=4)
    good = srv.submit(ReconRequest(spec=spec, sino=y, solver="fbp"))
    bad_shape = srv.submit(ReconRequest(spec=spec, sino=jnp.zeros((2, 2, 2)),
                                        solver="fbp"))
    bad_solver = srv.submit(ReconRequest(spec=spec, sino=y, solver="magic"))
    done = srv.drain()
    assert done[good].ok
    assert not done[bad_shape].ok and "shape" in done[bad_shape].error
    assert not done[bad_solver].ok and "solver" in done[bad_solver].error
    # invalid requests never reached a packed batch
    dispatched = {r for rec in srv.dispatch_log for r in rec["rids"]}
    assert dispatched == {good}


def test_executor_failure_isolates_poisoned_request(world):
    """When a packed dispatch fails, batch mates are re-run individually:
    only the poisoned request is answered with an error."""
    spec, y = world["par"]
    srv = CTServer(max_batch=4)
    srv.warm(spec, "fbp", batch_sizes=(1, 4))
    key = srv.bucket_key(ReconRequest(spec=spec, sino=y, solver="fbp"))
    real_single = srv._executor(key, 1)

    def exploding_batch(batch):
        raise RuntimeError("batch executor blew up")

    def picky_single(batch):
        if float(np.asarray(batch).sum()) < 0:
            raise RuntimeError("poisoned request")
        return real_single(batch)

    srv._executors[(key, 4)] = exploding_batch
    srv._executors[(key, 1)] = picky_single

    good = [srv.submit(ReconRequest(spec=spec, sino=y, solver="fbp"))
            for _ in range(3)]
    poisoned = srv.submit(ReconRequest(spec=spec, sino=-jnp.abs(y),
                                       solver="fbp"))
    done = srv.drain()
    expect = np.asarray(Projector(spec).fbp(y))
    for rid in good:
        assert done[rid].ok, done[rid].error
        np.testing.assert_allclose(np.asarray(done[rid].image), expect,
                                   rtol=1e-5, atol=1e-7)
    assert not done[poisoned].ok
    assert "poisoned" in done[poisoned].error


def test_warm_server_compiles_nothing_on_request_path(world, monkeypatch):
    """The warm-path guarantee: after warm(), traffic across every batch
    size class triggers zero autotune sweeps and zero new op-cache entries
    (with the tune disk cache enabled, as in production)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE", "1")
    (s_par, y_par), (s_fan, y_fan) = world["par"], world["fan"]
    srv = CTServer(max_batch=4)
    srv.warm(s_par, "fbp")
    srv.warm(s_fan, "fbp")
    srv.warm(s_par, "sirt", {"n_iters": 3})

    sweeps0 = tune.sweep_count()
    stats0 = ops.cache_stats()
    executors0 = set(srv._executors)

    rids = []
    for n in (1, 2, 3, 4, 4):          # every size class, twice the largest
        for _ in range(n):
            rids.append(srv.submit(
                ReconRequest(spec=s_par, sino=y_par, solver="fbp")))
        srv.drain()
    rids.append(srv.submit(ReconRequest(spec=s_fan, sino=y_fan,
                                        solver="fbp")))
    rids.append(srv.submit(ReconRequest(spec=s_par, sino=y_par,
                                        solver="sirt",
                                        solver_kwargs={"n_iters": 3})))
    done = srv.drain()
    assert all(done[r].ok for r in rids)

    assert tune.sweep_count() == sweeps0, "autotune swept on the request path"
    stats1 = ops.cache_stats()
    assert stats1["size"] == stats0["size"], "new op-cache entry built"
    assert stats1["misses"] == stats0["misses"], "op-cache miss on request path"
    assert set(srv._executors) == executors0, "new executor compiled"

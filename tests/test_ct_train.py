"""Unit tests for the projector-in-the-loop training subsystem
(:mod:`repro.launch.ct_train`): config validation, a short end-to-end fit on
each model family, and the trainer-state checkpoint round-trip (params +
optimizer state + EMA + data-pipeline cursor)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.ct_train import (GEOMETRIES, CTTrainer, TrainConfig,
                                   build_geometry, smoke_config)


def tiny(geometry="sparse_fan", **kw):
    base = dict(geometry=geometry, n=12, steps=3, batch=2, base=8, levels=1,
                depth=1, warmup=1, ema_warmup=2, refine_iters=5,
                model="unet" if geometry != "limited_angle" else "auto")
    base.update(kw)
    return TrainConfig(**base)


# --------------------------------------------------------------------------- #
# Config
# --------------------------------------------------------------------------- #
def test_config_validation():
    with pytest.raises(ValueError):
        TrainConfig(geometry="cone_spiral")
    with pytest.raises(ValueError):
        TrainConfig(geometry="helical", model="hybrid")
    with pytest.raises(ValueError):
        TrainConfig(geometry="helical", nz=1)
    with pytest.raises(ValueError):
        TrainConfig(n=4)
    with pytest.raises(ValueError):
        TrainConfig(dc_weight=-0.1)


def test_config_auto_resolution():
    cfg = TrainConfig(geometry="limited_angle")
    assert cfg.nz == 1 and cfg.resolved_model == "hybrid"
    assert cfg.mask_mode == "limited_angle"
    cfg = TrainConfig(geometry="helical")
    assert cfg.nz == 8 and cfg.resolved_model == "unet"
    assert cfg.mask_mode == "few_view"
    assert cfg.replace(nz=4).nz == 4


def test_smoke_configs_build_for_all_geometries():
    for g in GEOMETRIES:
        cfg = smoke_config(g)
        geom = build_geometry(cfg)
        assert geom.vol.shape == (cfg.n, cfg.n, cfg.nz)
        assert geom.n_angles >= 8


# --------------------------------------------------------------------------- #
# Training end-to-end (tiny)
# --------------------------------------------------------------------------- #
def test_fit_and_evaluate_unet():
    trainer = CTTrainer(tiny("sparse_fan"))
    losses = trainer.fit(log_every=0)
    assert len(losses) == 3 and all(np.isfinite(losses))
    m = trainer.evaluate(n_test=1)
    for k in ("psnr_net", "ssim_net", "psnr_refined", "ssim_refined",
              "dc_net", "dc_refined"):
        assert np.isfinite(m[k]), k
    assert 0.0 <= m["ssim_refined"] <= 1.0
    assert m["dc_refined"] <= m["dc_net"] + 1e-6


def test_fit_hybrid_limited_angle():
    trainer = CTTrainer(tiny("limited_angle"))
    assert set(trainer.params) == {"ctnet", "unet"}
    losses = trainer.fit(log_every=0)
    assert all(np.isfinite(losses))
    # hybrid predict returns a completed sinogram alongside the volume
    imgs, masks = trainer.pipe.batch(0)
    sino = trainer.proj(trainer._as_volume(imgs))
    m4 = jnp.asarray(masks)[:, :, None, None]
    pred, completed = trainer.predict(trainer.params, sino * m4,
                                      jnp.asarray(masks))
    assert pred.shape == (2, 12, 12, 1)
    assert completed is not None and completed.shape == sino.shape


@pytest.mark.slow
def test_fit_helical_volumetric():
    trainer = CTTrainer(tiny("helical", nz=2, n=12, batch=1))
    losses = trainer.fit(log_every=0)
    assert all(np.isfinite(losses))
    m = trainer.evaluate(n_test=1)
    assert np.isfinite(m["psnr_refined"])


def test_loss_grads_flow_through_dc_term():
    """dc_weight must change the gradient — the projector really is inside
    the differentiation path, not just the data generator."""
    trainer_on = CTTrainer(tiny("sparse_fan", dc_weight=1.0))
    trainer_off = CTTrainer(tiny("sparse_fan", dc_weight=0.0))
    imgs, masks = trainer_on.pipe.batch(0)
    gt = trainer_on._as_volume(imgs)
    sino = trainer_on.proj(gt)
    g_on = jax.grad(trainer_on.loss_fn)(trainer_on.params, sino,
                                        jnp.asarray(masks), gt)
    g_off = jax.grad(trainer_off.loss_fn)(trainer_off.params, sino,
                                          jnp.asarray(masks), gt)
    diff = sum(float(jnp.abs(a - b).sum()) for a, b in
               zip(jax.tree.leaves(g_on), jax.tree.leaves(g_off)))
    assert diff > 0


# --------------------------------------------------------------------------- #
# Checkpoint round-trip
# --------------------------------------------------------------------------- #
def test_checkpoint_roundtrip_full_trainer_state(tmp_path):
    cfg = tiny("sparse_fan", steps=4, ckpt_dir=str(tmp_path / "ck"),
               ckpt_every=2)
    t1 = CTTrainer(cfg)
    losses = t1.fit(log_every=0)
    assert len(losses) == 4

    t2 = CTTrainer(cfg)
    assert t2.resume() == 4
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(t1.ema), jax.tree.leaves(t2.ema)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(t1.opt_state),
                    jax.tree.leaves(t2.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert t2.pipe.state_dict() == t1.pipe.state_dict()
    # fit() on the restored trainer is a no-op (schedule already finished)
    assert t2.fit(log_every=0) == []


def test_resume_without_checkpoint_is_fresh_start(tmp_path):
    cfg = tiny("sparse_fan", ckpt_dir=str(tmp_path / "never_written"))
    t = CTTrainer(cfg)
    assert t.resume() == 0 and t.step == 0

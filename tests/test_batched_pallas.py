"""Lane-packed / view-folded batched Pallas paths vs the oracles.

These tests are deliberately hypothesis-free: they are the always-on
correctness anchor for every kernel code path (unbatched, view-blocked,
lane-packed batched) against the pure-jnp oracle and the seed per-sample
vmap path, plus the matched-pair adjoint property the paper requires.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Projector, VolumeGeometry, cone_beam, fan_beam,
                        parallel_beam)
from repro.core.geometry import cone_as_modular
from repro.kernels import ops, ref
from repro.kernels.fp_fan import bp_fan_sf_pallas, fp_fan_sf_pallas
from repro.kernels.fp_par import bp_parallel_sf_pallas, fp_parallel_sf_pallas
from repro.kernels.tune import KernelConfig

RTOL = ATOL = 2e-4


def _assert_close(a, b, tol=RTOL):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


# --------------------------------------------------------------------------- #
# Unbatched kernels vs oracle (always-on mirror of the hypothesis suite)
# --------------------------------------------------------------------------- #
SHAPES = [
    (16, 16, 4, 6, 4, 24),     # nx, ny, nz, na, nv, nu
    (24, 24, 2, 5, 2, 40),     # non-multiple-of-tile sizes
]


@pytest.mark.parametrize("shape", SHAPES)
def test_fp_bp_match_oracle(shape):
    nx, ny, nz, na, nv, nu = shape
    g = parallel_beam(na, nv, nu, VolumeGeometry(nx, ny, nz))
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(fp_parallel_sf_pallas(f, g), ref.forward(f, g, "sf"))
    _assert_close(bp_parallel_sf_pallas(y, g), ref.adjoint(y, g, "sf"))


@pytest.mark.parametrize("ba,bab", [(2, 2), (4, 3)])
def test_view_blocking_matches_oracle(ba, bab):
    """ba/bab > 1 (view-blocked FP/BP) is exactly the unblocked math."""
    g = parallel_beam(7, 4, 24, VolumeGeometry(16, 16, 4))
    cfg = KernelConfig(ba=ba, bab=bab)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(fp_parallel_sf_pallas(f, g, config=cfg),
                  ref.forward(f, g, "sf"))
    _assert_close(bp_parallel_sf_pallas(y, g, config=cfg),
                  ref.adjoint(y, g, "sf"))


# --------------------------------------------------------------------------- #
# Lane-packed batching (parallel)
# --------------------------------------------------------------------------- #
BATCH_SHAPES = [
    (5, 16, 16, 4, 6, 4, 24),    # B, nx, ny, nz, na, nv, nu
    (8, 32, 32, 1, 12, 1, 48),   # the paper's thin-z 2D training regime
    (3, 24, 24, 2, 5, 2, 40),    # nothing tile-aligned
]


@pytest.mark.parametrize("shape", BATCH_SHAPES)
def test_lane_packed_fp_matches_vmap_and_oracle(shape):
    B, nx, ny, nz, na, nv, nu = shape
    g = parallel_beam(na, nv, nu, VolumeGeometry(nx, ny, nz))
    fb = jax.random.normal(jax.random.PRNGKey(0), (B, nx, ny, nz))
    packed = fp_parallel_sf_pallas(fb, g)
    assert packed.shape == (B,) + g.sino_shape
    vmapped = jax.vmap(lambda x: fp_parallel_sf_pallas(x, g))(fb)
    oracle = jax.vmap(lambda x: ref.forward(x, g, "sf"))(fb)
    _assert_close(packed, oracle)
    _assert_close(packed, vmapped, tol=1e-4)   # seed path agreement <= 1e-4


@pytest.mark.parametrize("shape", BATCH_SHAPES[:2])
def test_lane_packed_bp_matches_vmap_and_oracle(shape):
    B, nx, ny, nz, na, nv, nu = shape
    g = parallel_beam(na, nv, nu, VolumeGeometry(nx, ny, nz))
    yb = jax.random.normal(jax.random.PRNGKey(1), (B,) + g.sino_shape)
    packed = bp_parallel_sf_pallas(yb, g)
    assert packed.shape == (B, nx, ny, nz)
    oracle = jax.vmap(lambda q: ref.adjoint(q, g, "sf"))(yb)
    _assert_close(packed, oracle)
    _assert_close(packed, jax.vmap(lambda q: bp_parallel_sf_pallas(q, g))(yb),
                  tol=1e-4)


def test_lane_packed_pair_is_matched():
    """<A x, y> == <x, A^T y> on the batched lane-packed pallas path."""
    g = parallel_beam(10, 2, 36, VolumeGeometry(24, 24, 2))
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (6,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (6,) + g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


def test_lane_packed_gradient_is_backprojection():
    """The custom_vjp wiring holds on the batched path: the gradient of the
    data-consistency loss is exactly the batched backprojection."""
    g = parallel_beam(8, 1, 30, VolumeGeometry(20, 20, 1))
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (4,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (4,) + g.sino_shape)
    grad = jax.grad(lambda x: 0.5 * jnp.sum((proj(x) - y) ** 2))(x)
    _assert_close(grad, proj.T(proj(x) - y), tol=1e-4)


def test_multi_leading_dims_flatten_through_kernel():
    g = parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2))
    f = jax.random.normal(jax.random.PRNGKey(0), (2, 3) + g.vol.shape)
    out = ops.forward_project(f, g, "sf", backend="pallas")
    assert out.shape == (2, 3) + g.sino_shape
    _assert_close(out[1, 2], ref.forward(f[1, 2], g, "sf"))


# --------------------------------------------------------------------------- #
# Always-on mirrors of non-property coverage that lives in hypothesis-gated
# modules (test_kernels.py skips entirely when hypothesis is missing)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 0.05)])
def test_fp_dtypes(dtype, tol):
    g = parallel_beam(6, 4, 24, VolumeGeometry(16, 16, 4))
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape).astype(dtype)
    p_ref = ref.forward(f.astype(jnp.float32), g, "sf")
    p_pal = fp_parallel_sf_pallas(f, g).astype(jnp.float32)
    err = float(jnp.abs(p_pal - p_ref).max())
    assert err <= tol * float(jnp.abs(p_ref).max()), err


def test_fp_anisotropic_pixels():
    g = parallel_beam(8, 6, 30, VolumeGeometry(20, 20, 4, dx=1.5, dy=1.5,
                                               dz=2.0),
                      pixel_width=1.1, pixel_height=1.3)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    _assert_close(fp_parallel_sf_pallas(f, g), ref.forward(f, g, "sf"))


def test_kernel_registered_dispatch():
    assert ("parallel", "sf") in ops._KERNEL_TABLE
    assert ("cone", "sf") in ops._KERNEL_TABLE
    g = parallel_beam(6, 4, 24, VolumeGeometry(16, 16, 4))
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    out = ops.forward_project(f, g, "sf", backend="pallas")
    _assert_close(out, ref.forward(f, g, "sf"))


def _dot_test(proj, key=0, tol=1e-4):
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, proj.vol_shape())
    y = jax.random.normal(ky, proj.sino_shape())
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < tol, (lhs, rhs)


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_parallel_matched(model):
    _dot_test(Projector(parallel_beam(10, 6, 36, VolumeGeometry(24, 24, 6)),
                        model))


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_cone_matched(model):
    g = cone_beam(8, 12, 36, VolumeGeometry(24, 24, 8), sod=120.0, sdd=240.0,
                  pixel_width=2.0, pixel_height=2.0)
    _dot_test(Projector(g, model))


def test_cone_curved_matched():
    g = cone_beam(8, 12, 36, VolumeGeometry(24, 24, 8), sod=120.0, sdd=240.0,
                  pixel_width=2.0, pixel_height=2.0, detector_type="curved")
    _dot_test(Projector(g, "joseph"))


def test_modular_matched():
    g = cone_as_modular(cone_beam(6, 10, 30, VolumeGeometry(20, 20, 6),
                                  sod=100.0, sdd=200.0,
                                  pixel_width=2.0, pixel_height=2.0))
    _dot_test(Projector(g))


def test_double_differentiation():
    """grad of back_project (A^T)^T == A: the pair is self-consistent."""
    g = parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2))
    proj = Projector(g, "sf")
    y = jax.random.normal(jax.random.PRNGKey(0), g.sino_shape)
    x = jax.random.normal(jax.random.PRNGKey(1), g.vol.shape)
    grad_y = jax.grad(lambda y: jnp.vdot(proj.T(y), x))(y)
    _assert_close(grad_y, proj(x), tol=1e-4)


CONE_SHAPES = [
    # nx, ny, nz, na, nv, nu, sod, sdd
    (16, 16, 8, 6, 8, 24, 80.0, 160.0),
    (24, 24, 4, 5, 8, 36, 120.0, 200.0),    # non-tile-multiple views/rows
]


@pytest.mark.parametrize("shape", CONE_SHAPES)
def test_fp_cone_matches_oracle(shape):
    from repro.kernels.fp_cone import fp_cone_sf_pallas
    nx, ny, nz, na, nv, nu, sod, sdd = shape
    g = cone_beam(na, nv, nu, VolumeGeometry(nx, ny, nz), sod=sod, sdd=sdd,
                  pixel_width=2.0, pixel_height=2.0)
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    _assert_close(fp_cone_sf_pallas(f, g, bu=8, bv=8),
                  ref.forward(f, g, "sf"), tol=3e-4)


# --------------------------------------------------------------------------- #
# Lane-packed batching (fan: the pre-collapsed-axial cone case)
# --------------------------------------------------------------------------- #
FAN_BATCH_SHAPES = [
    # B, nx, ny, nz, na, nv, nu, det
    (5, 16, 16, 4, 6, 4, 24, "flat"),
    (4, 20, 20, 1, 8, 1, 32, "curved"),   # thin-z 2D training regime
]


@pytest.mark.parametrize("shape", FAN_BATCH_SHAPES)
def test_fan_lane_packed_fp_matches_vmap_and_oracle(shape):
    B, nx, ny, nz, na, nv, nu, det = shape
    g = fan_beam(na, nv, nu, VolumeGeometry(nx, ny, nz), sod=70.0, sdd=140.0,
                 pixel_width=2.0, detector_type=det)
    fb = jax.random.normal(jax.random.PRNGKey(0), (B, nx, ny, nz))
    packed = fp_fan_sf_pallas(fb, g)
    assert packed.shape == (B,) + g.sino_shape
    vmapped = jax.vmap(lambda x: fp_fan_sf_pallas(x, g))(fb)
    oracle = jax.vmap(lambda x: ref.forward(x, g, "sf"))(fb)
    _assert_close(packed, oracle)
    _assert_close(packed, vmapped, tol=1e-4)


@pytest.mark.parametrize("shape", FAN_BATCH_SHAPES[:1])
def test_fan_lane_packed_bp_matches_vmap_and_oracle(shape):
    B, nx, ny, nz, na, nv, nu, det = shape
    g = fan_beam(na, nv, nu, VolumeGeometry(nx, ny, nz), sod=70.0, sdd=140.0,
                 pixel_width=2.0, detector_type=det)
    yb = jax.random.normal(jax.random.PRNGKey(1), (B,) + g.sino_shape)
    packed = bp_fan_sf_pallas(yb, g)
    assert packed.shape == (B, nx, ny, nz)
    oracle = jax.vmap(lambda q: ref.adjoint(q, g, "sf"))(yb)
    _assert_close(packed, oracle)
    _assert_close(packed, jax.vmap(lambda q: bp_fan_sf_pallas(q, g))(yb),
                  tol=1e-4)


def test_fan_lane_packed_pair_is_matched():
    """<A x, y> == <x, A^T y> on the batched lane-packed fan pallas path."""
    g = fan_beam(8, 2, 32, VolumeGeometry(20, 20, 2), sod=70.0, sdd=140.0,
                 pixel_width=2.0)
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (4,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (4,) + g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


@pytest.mark.parametrize("shape", CONE_SHAPES)
def test_bp_cone_matches_oracle(shape):
    """Always-on mirror of the hypothesis-gated cone BP-vs-oracle check."""
    from repro.kernels.fp_cone import bp_cone_sf_pallas
    nx, ny, nz, na, nv, nu, sod, sdd = shape
    g = cone_beam(na, nv, nu, VolumeGeometry(nx, ny, nz), sod=sod, sdd=sdd,
                  pixel_width=2.0, pixel_height=2.0)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(bp_cone_sf_pallas(y, g, bg=8, bv=8),
                  ref.adjoint(y, g, "sf"), tol=3e-4)


# --------------------------------------------------------------------------- #
# Batched cone (view-axis folding)
# --------------------------------------------------------------------------- #
def test_cone_batched_fp_matches_vmap():
    from repro.kernels.fp_cone import fp_cone_sf_pallas
    g = cone_beam(5, 8, 24, VolumeGeometry(16, 16, 8), sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    fb = jax.random.normal(jax.random.PRNGKey(0), (3,) + g.vol.shape)
    batched = fp_cone_sf_pallas(fb, g, bu=8, bv=8)
    assert batched.shape == (3,) + g.sino_shape
    oracle = jax.vmap(lambda x: ref.forward(x, g, "sf"))(fb)
    _assert_close(batched, oracle, tol=3e-4)


def test_cone_pallas_pair_matched_unclamped_z_window():
    """Always-on mirror of the tall-stack adjoint case: nz far larger than
    the kernels' axial window NZW, so the z-window genuinely slides."""
    g = cone_beam(6, 8, 24, VolumeGeometry(16, 16, 24), sod=100.0, sdd=150.0,
                  pixel_width=2.0, pixel_height=1.0)
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


def test_cone_batched_bp_matches_vmap_and_oracle():
    """Gathered-axis batch folding in the cone BP == per-sample results."""
    from repro.kernels.fp_cone import bp_cone_sf_pallas
    g = cone_beam(5, 8, 24, VolumeGeometry(16, 16, 8), sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    yb = jax.random.normal(jax.random.PRNGKey(1), (3,) + g.sino_shape)
    batched = bp_cone_sf_pallas(yb, g, bg=8, bv=8)
    assert batched.shape == (3,) + g.vol.shape
    oracle = jax.vmap(lambda q: ref.adjoint(q, g, "sf"))(yb)
    _assert_close(batched, oracle, tol=3e-4)
    _assert_close(batched,
                  jax.vmap(lambda q: bp_cone_sf_pallas(q, g, bg=8, bv=8))(yb),
                  tol=1e-4)


def test_cone_batched_pair_is_matched():
    g = cone_beam(4, 8, 24, VolumeGeometry(16, 16, 8), sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    proj = Projector(g, "sf", backend="pallas")
    x = jax.random.normal(jax.random.PRNGKey(0), (2,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (2,) + g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


# --------------------------------------------------------------------------- #
# Mixed precision + BP stripe reuse (always-on anchors; the property sweep
# lives in the hypothesis-gated test_kernels.py)
# --------------------------------------------------------------------------- #
from repro.kernels import precision  # noqa: E402


@pytest.mark.parametrize("bs", [2, 4])
def test_bp_stripe_reuse_is_exact(bs):
    """bs > 1 only re-blocks the gathered axis: results are identical (to
    f32 roundoff) to the unblocked BP, both parallel and fan."""
    gp = parallel_beam(7, 4, 24, VolumeGeometry(16, 16, 4))
    gf = fan_beam(6, 4, 24, VolumeGeometry(16, 16, 4), sod=70.0, sdd=140.0,
                  pixel_width=2.0)
    yp = jax.random.normal(jax.random.PRNGKey(1), gp.sino_shape)
    yf = jax.random.normal(jax.random.PRNGKey(2), gf.sino_shape)
    _assert_close(bp_parallel_sf_pallas(yp, gp, bg=8, bs=bs),
                  ref.adjoint(yp, gp, "sf"))
    _assert_close(bp_fan_sf_pallas(yf, gf, bg=8, bs=bs),
                  ref.adjoint(yf, gf, "sf"))


def test_bp_stripe_reuse_clamps_small_volumes():
    """bs larger than the gathered axis allows is clamped, not an error."""
    g = parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2))
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    _assert_close(bp_parallel_sf_pallas(y, g, bg=16, bs=8),
                  ref.adjoint(y, g, "sf"))


_BF16_KERNELS = [
    ("parallel", lambda: parallel_beam(6, 4, 24, VolumeGeometry(16, 16, 4))),
    ("fan", lambda: fan_beam(6, 4, 24, VolumeGeometry(16, 16, 4), sod=70.0,
                             sdd=140.0, pixel_width=2.0)),
    ("cone", lambda: cone_beam(6, 8, 24, VolumeGeometry(16, 16, 8), sod=80.0,
                               sdd=160.0, pixel_width=2.0, pixel_height=2.0)),
]


@pytest.mark.parametrize("name,mk", _BF16_KERNELS, ids=[n for n, _ in _BF16_KERNELS])
def test_bf16_fp_bp_error_within_documented_bound(name, mk):
    """compute_dtype="bfloat16" stays within BF16_FP_REL_BOUND of the f32
    oracle for every registered pair, and actually perturbs the numerics
    (i.e. the policy reached the kernel, not a silent f32 fallback)."""
    g = mk()
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    s_ref = ref.forward(f, g, "sf")
    b_ref = ref.adjoint(y, g, "sf")
    s = ops.forward_project(f, g, "sf", backend="pallas", mode="exact",
                            compute_dtype="bfloat16")
    b = ops.back_project(y, g, "sf", backend="pallas", mode="exact",
                         compute_dtype="bfloat16")
    assert s.dtype == jnp.float32 and b.dtype == jnp.float32
    rel_s = float(jnp.abs(s - s_ref).max() / jnp.abs(s_ref).max())
    rel_b = float(jnp.abs(b - b_ref).max() / jnp.abs(b_ref).max())
    assert 1e-5 < rel_s < precision.BF16_FP_REL_BOUND, rel_s
    assert 1e-5 < rel_b < precision.BF16_FP_REL_BOUND, rel_b


def test_bf16_matches_quantized_oracle():
    """The dtype-matched oracle (ref.forward(dtype="bfloat16")) quantizes
    the data stream the way the kernel tiles do, so kernel-vs-oracle
    distance shrinks well below the full bf16 bound."""
    g = parallel_beam(6, 4, 24, VolumeGeometry(16, 16, 4))
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    s_k = fp_parallel_sf_pallas(f, g, compute_dtype="bfloat16")
    s_q = ref.forward(f, g, "sf", dtype="bfloat16")
    assert s_q.dtype == jnp.float32
    rel = float(jnp.abs(s_k - s_q).max() / jnp.abs(s_q).max())
    assert rel < precision.BF16_DOT_TOL, rel


def test_bf16_batched_lane_packed_paths():
    """The lane-packed batched FP/BP honor the policy too (bf16 tiles, f32
    out) — the rows the perf gate targets."""
    g = parallel_beam(8, 1, 30, VolumeGeometry(20, 20, 1))
    fb = jax.random.normal(jax.random.PRNGKey(0), (4,) + g.vol.shape)
    yb = jax.random.normal(jax.random.PRNGKey(1), (4,) + g.sino_shape)
    s = fp_parallel_sf_pallas(fb, g, compute_dtype="bfloat16")
    b = bp_parallel_sf_pallas(yb, g, compute_dtype="bfloat16", bs=2)
    assert s.dtype == jnp.float32 and b.dtype == jnp.float32
    s_ref = jax.vmap(lambda x: ref.forward(x, g, "sf"))(fb)
    b_ref = jax.vmap(lambda q: ref.adjoint(q, g, "sf"))(yb)
    assert float(jnp.abs(s - s_ref).max()
                 / jnp.abs(s_ref).max()) < precision.BF16_FP_REL_BOUND
    assert float(jnp.abs(b - b_ref).max()
                 / jnp.abs(b_ref).max()) < precision.BF16_FP_REL_BOUND

"""The paper's central correctness property: matched projector pairs.

<A x, y> == <x, A^T y> must hold to float tolerance for every geometry x
model x backend combination — otherwise CG/least-squares gradients are wrong
and 1000+-iteration recon diverges (paper §2.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    # Only the property tests need hypothesis; the fixed-geometry dot-tests
    # (incl. the modular Pallas pair's ~1e-6 acceptance tests) must run in
    # minimal environments too, so the module no longer importorskips.
    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(reason="property test needs hypothesis")(f)
        return deco

    def settings(*a, **k):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _NullStrategies()

from repro.core import (Projector, VolumeGeometry, cone_beam, fan_beam,
                        helical_beam, parallel_beam)
from repro.core.geometry import cone_as_modular


def _dot_test(proj, key=0, tol=1e-4):
    # fp32 accumulation noise over ~1e5-term reductions is ~4e-5 relative;
    # an *unmatched* pair fails this at the 1e-2..1e-1 level.
    kx, ky = jax.random.split(jax.random.PRNGKey(key))
    x = jax.random.normal(kx, proj.vol_shape())
    y = jax.random.normal(ky, proj.sino_shape())
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < tol, (lhs, rhs)


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_parallel_matched(model):
    v = VolumeGeometry(24, 24, 6)
    g = parallel_beam(10, 6, 36, v)
    _dot_test(Projector(g, model))


@pytest.mark.parametrize("model", ["joseph", "sf"])
def test_cone_matched(model):
    v = VolumeGeometry(24, 24, 8)
    g = cone_beam(8, 12, 36, v, sod=120.0, sdd=240.0,
                  pixel_width=2.0, pixel_height=2.0)
    _dot_test(Projector(g, model))


def test_cone_curved_matched():
    v = VolumeGeometry(24, 24, 8)
    g = cone_beam(8, 12, 36, v, sod=120.0, sdd=240.0, pixel_width=2.0,
                  pixel_height=2.0, detector_type="curved")
    _dot_test(Projector(g, "joseph"))


# Flat-detector cone Pallas matched pair (FP and BP both on-kernel) across
# cone angles.  The last case has nz far larger than the kernels' axial
# window NZW, so the z-window genuinely slides (is not clamped to the full
# volume) — the regime where a mismatched FP/BP windowing would show up.
CONE_PALLAS_GEOMS = [
    # nz, n_rows, pixel_height, sod, sdd
    (8, 12, 2.0, 120.0, 240.0),      # ~11 deg half cone angle
    (8, 16, 3.0, 80.0, 160.0),       # wide cone (~17 deg)
    (24, 8, 1.0, 100.0, 150.0),      # tall stack: un-clamped sliding z-window
]


@pytest.mark.parametrize("nz,nv,dv,sod,sdd", CONE_PALLAS_GEOMS)
def test_cone_pallas_pair_matched_angles(nz, nv, dv, sod, sdd):
    v = VolumeGeometry(16, 16, nz)
    g = cone_beam(6, nv, 24, v, sod=sod, sdd=sdd,
                  pixel_width=2.0, pixel_height=dv)
    _dot_test(Projector(g, "sf", backend="pallas"))


def test_cone_pallas_bp_gradient_is_forward():
    """grad_y <A^T y, x> == A x on the registered cone Pallas pair — the
    custom_vjp wiring routes through the new Pallas BP's transpose."""
    v = VolumeGeometry(16, 16, 8)
    g = cone_beam(5, 8, 24, v, sod=80.0, sdd=160.0,
                  pixel_width=2.0, pixel_height=2.0)
    proj = Projector(g, "sf", backend="pallas")
    y = jax.random.normal(jax.random.PRNGKey(0), g.sino_shape)
    x = jax.random.normal(jax.random.PRNGKey(1), v.shape)
    grad_y = jax.grad(lambda q: jnp.vdot(proj.T(q), x))(y)
    np.testing.assert_allclose(np.asarray(grad_y), np.asarray(proj(x)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("det", ["flat", "curved"])
def test_fan_matched(det):
    v = VolumeGeometry(24, 24, 4)
    g = fan_beam(8, 4, 36, v, sod=120.0, sdd=240.0, pixel_width=2.0,
                 detector_type=det)
    _dot_test(Projector(g, "sf"))


@pytest.mark.parametrize("det", ["flat", "curved"])
def test_fan_pallas_pair_matched(det):
    v = VolumeGeometry(24, 24, 4)
    g = fan_beam(8, 4, 36, v, sod=120.0, sdd=240.0, pixel_width=2.0,
                 detector_type=det)
    _dot_test(Projector(g, "sf", backend="pallas"))


def test_modular_matched():
    v = VolumeGeometry(20, 20, 6)
    g = cone_as_modular(cone_beam(6, 10, 30, v, sod=100.0, sdd=200.0,
                                  pixel_width=2.0, pixel_height=2.0))
    _dot_test(Projector(g))


# Modular Pallas matched pair (FP and BP both on-kernel) across frame
# regimes: an axial circular trajectory re-expressed as modular frames, and
# genuinely helical scans (source translating in z) incl. a tall volume
# where the kernel's axial window slides.
def test_modular_pallas_pair_matched_cone_frames():
    v = VolumeGeometry(20, 20, 6)
    g = cone_as_modular(cone_beam(6, 10, 30, v, sod=100.0, sdd=200.0,
                                  pixel_width=2.0, pixel_height=2.0))
    _dot_test(Projector(g, "sf", backend="pallas"))


@pytest.mark.parametrize("nz,pitch,nv", [(8, 8.0, 10), (24, 16.0, 6)])
def test_modular_pallas_pair_matched_helical(nz, pitch, nv):
    v = VolumeGeometry(16, 16, nz)
    g = helical_beam(1.0, pitch, 6, nv, 24, v, sod=80.0, sdd=160.0,
                     pixel_width=2.0, pixel_height=2.0)
    _dot_test(Projector(g, "sf", backend="pallas"))


def test_modular_pallas_pair_matched_batched():
    """<A x, y> == <x, A^T y> through the grid-folded batched modular pair."""
    from repro.kernels import fp_modular
    v = VolumeGeometry(16, 16, 8)
    g = helical_beam(1.0, 8.0, 6, 8, 24, v, sod=80.0, sdd=160.0,
                     pixel_width=2.0, pixel_height=2.0)
    kx, ky = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (3,) + v.shape)
    y = jax.random.normal(ky, (3,) + g.sino_shape)
    lhs = jnp.vdot(fp_modular.fp_modular_sf_pallas(x, g), y)
    rhs = jnp.vdot(x, fp_modular.bp_modular_sf_pallas(y, g))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


def test_modular_pallas_bp_gradient_is_forward():
    """grad_y <A^T y, x> == A x on the registered modular Pallas pair."""
    v = VolumeGeometry(16, 16, 8)
    g = helical_beam(1.0, 8.0, 5, 8, 24, v, sod=80.0, sdd=160.0,
                     pixel_width=2.0, pixel_height=2.0)
    proj = Projector(g, "sf", backend="pallas")
    y = jax.random.normal(jax.random.PRNGKey(0), g.sino_shape)
    x = jax.random.normal(jax.random.PRNGKey(1), v.shape)
    grad_y = jax.grad(lambda q: jnp.vdot(proj.T(q), x))(y)
    np.testing.assert_allclose(np.asarray(grad_y), np.asarray(proj(x)),
                               rtol=1e-4, atol=1e-4)


def test_pallas_pair_matched():
    v = VolumeGeometry(24, 24, 6)
    g = parallel_beam(10, 6, 36, v)
    _dot_test(Projector(g, "sf", backend="pallas"))


@settings(max_examples=8, deadline=None)
@given(na=st.integers(3, 12), nu=st.integers(16, 40),
       off=st.floats(-3.0, 3.0), du=st.floats(0.6, 2.0), seed=st.integers(0, 100))
def test_parallel_matched_property(na, nu, off, du, seed):
    """Property over randomized geometries (non-equispaced angles, shifts,
    anisotropic pixel sizes)."""
    rng = np.random.default_rng(seed)
    ang = np.sort(rng.uniform(0, np.pi, na))
    v = VolumeGeometry(16, 16, 4, offset_x=off)
    g = parallel_beam(na, 4, nu, v, angles=ang, pixel_width=du,
                      center_col=off / 2)
    _dot_test(Projector(g, "sf"), key=seed)


@settings(max_examples=6, deadline=None)
@given(sod=st.floats(60.0, 200.0), mag=st.floats(1.2, 3.0),
       seed=st.integers(0, 100))
def test_cone_matched_property(sod, mag, seed):
    v = VolumeGeometry(16, 16, 6)
    g = cone_beam(6, 10, 30, v, sod=sod, sdd=sod * mag,
                  pixel_width=2.0, pixel_height=2.0)
    _dot_test(Projector(g, "sf"), key=seed)


@settings(max_examples=6, deadline=None)
@given(sod=st.floats(60.0, 200.0), mag=st.floats(1.2, 3.0),
       curved=st.booleans(), seed=st.integers(0, 100))
def test_fan_matched_property(sod, mag, curved, seed):
    """Property over randomized fan geometries: flat + curved detectors,
    varying magnification."""
    v = VolumeGeometry(16, 16, 4)
    g = fan_beam(6, 4, 30, v, sod=sod, sdd=sod * mag, pixel_width=2.0,
                 detector_type="curved" if curved else "flat")
    _dot_test(Projector(g, "sf"), key=seed)


def test_gradient_is_backprojection():
    """d/dx 0.5||Ax - y||^2 == A^T(Ax - y) exactly (custom_vjp wiring)."""
    v = VolumeGeometry(20, 20, 4)
    g = parallel_beam(8, 4, 30, v)
    proj = Projector(g, "sf")
    x = jax.random.normal(jax.random.PRNGKey(0), v.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    grad = jax.grad(lambda x: 0.5 * jnp.sum((proj(x) - y) ** 2))(x)
    expected = proj.T(proj(x) - y)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


def test_double_differentiation():
    """grad of back_project (A^T)^T == A: the pair is self-consistent."""
    v = VolumeGeometry(16, 16, 2)
    g = parallel_beam(6, 2, 24, v)
    proj = Projector(g, "sf")
    y = jax.random.normal(jax.random.PRNGKey(0), g.sino_shape)
    x = jax.random.normal(jax.random.PRNGKey(1), v.shape)
    grad_y = jax.grad(lambda y: jnp.vdot(proj.T(y), x))(y)
    np.testing.assert_allclose(np.asarray(grad_y), np.asarray(proj(x)),
                               rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
# bf16 tile precision (kernels/precision.py)
# --------------------------------------------------------------------------- #
# At compute_dtype="bfloat16" the FP quantizes the volume-side stream while
# the BP quantizes the sinogram-side stream, so <A x, y> and <x, A^T y> are
# inner products of *differently quantized* operators: they agree to
# O(BF16_EPS) relative (f32 accumulation keeps the error from compounding),
# which is the documented BF16_DOT_TOL.  An unmatched pair still fails this
# at the 1e-1 level, so the dot-test stays discriminating at bf16.
from repro.kernels import precision  # noqa: E402

BF16_GEOMS = {
    "parallel": lambda: parallel_beam(10, 6, 36, VolumeGeometry(24, 24, 6)),
    "fan": lambda: fan_beam(8, 4, 36, VolumeGeometry(24, 24, 4), sod=120.0,
                            sdd=240.0, pixel_width=2.0),
    "cone": lambda: cone_beam(8, 12, 36, VolumeGeometry(24, 24, 8),
                              sod=120.0, sdd=240.0, pixel_width=2.0,
                              pixel_height=2.0),
    "modular": lambda: helical_beam(1.0, 8.0, 6, 8, 24,
                                    VolumeGeometry(16, 16, 8), sod=80.0,
                                    sdd=160.0, pixel_width=2.0,
                                    pixel_height=2.0),
}


@pytest.mark.parametrize("name", sorted(BF16_GEOMS))
def test_bf16_pallas_pair_dot(name):
    g = BF16_GEOMS[name]()
    proj = Projector(g, "sf", backend="pallas", mode="exact",
                     compute_dtype="bfloat16")
    _dot_test(proj, tol=float(precision.BF16_DOT_TOL))


def test_bf16_packed_cone_pair_dot():
    g = BF16_GEOMS["cone"]()
    proj = Projector(g, "sf", backend="pallas", mode="packed",
                     compute_dtype="bfloat16")
    _dot_test(proj, tol=float(precision.BF16_DOT_TOL))


def test_bf16_gradient_is_backprojection():
    """At bf16 the custom_vjp wiring still routes the gradient through the
    *same* bf16 BP op, so grad == A^T(Ax - y) holds tightly (same closure,
    not merely the same math)."""
    g = BF16_GEOMS["parallel"]()
    proj = Projector(g, "sf", backend="pallas", compute_dtype="bfloat16")
    x = jax.random.normal(jax.random.PRNGKey(0), proj.vol_shape())
    y = jax.random.normal(jax.random.PRNGKey(1), proj.sino_shape())
    grad = jax.grad(lambda x: 0.5 * jnp.sum((proj(x) - y) ** 2))(x)
    expected = proj.T(proj(x) - y)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


def test_bf16_stripe_reuse_pair_dot():
    """The BP stripe-reuse blocking (bs > 1) preserves the matched pair."""
    from repro.kernels import fp_par
    g = BF16_GEOMS["parallel"]()
    kx, ky = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, g.vol.shape)
    y = jax.random.normal(ky, g.sino_shape)
    lhs = jnp.vdot(fp_par.fp_parallel_sf_pallas(x, g,
                                                compute_dtype="bfloat16"), y)
    rhs = jnp.vdot(x, fp_par.bp_parallel_sf_pallas(y, g, bs=4,
                                                   compute_dtype="bfloat16"))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < precision.BF16_DOT_TOL

"""Sharding rules + local-mesh integration (1 device: specs must degrade to
replicated without error; divisibility guards across all 10 archs)."""
import jax
import numpy as np
import pytest

from repro import configs
from repro.launch import sharding
from repro.launch.mesh import (dp_size, make_local_mesh,
                               make_production_mesh, tp_size)
from repro.models import model as MD


def test_local_mesh_and_axes():
    mesh = make_local_mesh()
    assert set(mesh.axis_names) == {"data", "model"}
    assert dp_size(mesh) * tp_size(mesh) == jax.device_count()


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_param_specs_valid_all_archs(arch):
    """Every parameter leaf gets a spec whose sharded dims divide evenly —
    checked on a virtual 16x16 mesh built from the abstract mesh shape."""
    cfg = configs.get(arch)
    aparams = MD.abstract_params(cfg)
    mesh = make_local_mesh()   # 1 device: still exercises the rule code
    shards = sharding.param_shardings(cfg, aparams, mesh)
    flat_p = jax.tree.leaves(aparams)
    flat_s = jax.tree.leaves(shards, is_leaf=lambda s: hasattr(s, "spec"))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        spec = s.spec
        assert len(spec) <= len(p.shape)
        for dim, ax in zip(p.shape, spec):
            if ax is None:
                continue
            sz = int(np.prod([mesh.shape[a] for a in
                              ((ax,) if isinstance(ax, str) else ax)]))
            assert dim % sz == 0


def test_divisibility_guard_degrades():
    """kv=5 heads on a 16-way model axis must degrade to replicated."""
    cfg = configs.get("hymba_1_5b")
    specs = MD.cache_shapes(cfg, 1, 1024)
    mesh = make_local_mesh()
    cs = sharding.cache_shardings(specs, mesh)
    for s in jax.tree.leaves(cs, is_leaf=lambda x: hasattr(x, "spec")):
        assert s.spec is not None


def test_train_step_on_local_mesh():
    """Full sharded train step executes on the real (1-device) mesh."""
    import dataclasses
    from repro.launch.steps import make_train_step
    from repro.optim import adamw, constant
    cfg = dataclasses.replace(configs.get_smoke("tinyllama_1_1b"), grad_accum=1)
    mesh = make_local_mesh()
    ac = sharding.make_ac(mesh, cfg)
    opt = adamw(constant(1e-3))
    step = make_train_step(cfg, opt, ac)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                          cfg.vocab_size)}
    with mesh:
        params, state, m = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(m["loss"]))


def test_grad_accum_equivalence():
    """grad_accum=2 must give (nearly) the same update as accum=1."""
    import dataclasses
    from repro.launch.steps import make_train_step
    from repro.optim import sgd, constant
    cfg = dataclasses.replace(configs.get_smoke("qwen3_0_6b"),
                              remat_policy="none")
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size)}
    outs = {}
    for ga in (1, 2):
        opt = sgd(constant(1e-2))
        step = make_train_step(cfg, opt, grad_accum=ga)
        p, _, m = jax.jit(step)(params, opt.init(params), batch)
        outs[ga] = (jax.tree.leaves(p)[0], float(m["loss"]))
    np.testing.assert_allclose(np.asarray(outs[1][0]), np.asarray(outs[2][0]),
                               rtol=2e-3, atol=2e-5)


def test_production_mesh_needs_512_devices():
    """On this 1-device process, building the 16x16 mesh must fail loudly —
    proving smoke tests don't silently use the dry-run's fake devices."""
    if jax.device_count() >= 256:
        pytest.skip("running under forced host device count")
    with pytest.raises(ValueError):
        make_production_mesh()

"""Reconstruction algorithms on the matched pairs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Projector, ProjectorSpec, VolumeGeometry, cone_beam,
                        parallel_beam)
from repro.data.phantoms import shepp_logan_2d
from repro.recon import (ReconResult, cgls, complete_and_refine, fista_tv,
                         sirt, tv_norm)


@pytest.fixture(scope="module")
def setup():
    vol = VolumeGeometry(48, 48, 1)
    g = parallel_beam(60, 1, 72, vol)
    f = jnp.asarray(shepp_logan_2d(vol)[:, :, None]) * 0.02
    proj = Projector(ProjectorSpec(g))
    return proj, f, proj(f)


def _rel(a, b):
    return float(jnp.linalg.norm((a - b).ravel()) / jnp.linalg.norm(b.ravel()))


def test_sirt_converges(setup):
    proj, f, y = setup
    x20 = sirt(proj, y, n_iters=20).image
    res = sirt(proj, y, n_iters=80)
    assert isinstance(res, ReconResult) and res.iterations == 80
    assert res.residual_history.shape == (80,)
    assert _rel(res.image, f) < _rel(x20, f) < _rel(jnp.zeros_like(f), f)
    assert _rel(res.image, f) < 0.25


def test_sirt_accepts_spec(setup):
    proj, f, y = setup
    from_spec = sirt(proj.spec, y, n_iters=10)
    from_proj = sirt(proj, y, n_iters=10)
    np.testing.assert_allclose(np.asarray(from_spec.image),
                               np.asarray(from_proj.image), rtol=0, atol=0)


def test_cgls_monotone_residual(setup):
    proj, f, y = setup
    res = cgls(proj, y, n_iters=25)
    h = np.asarray(res.residual_history)
    assert h.shape == (25,)
    assert h[-1] < 0.05 * h[0]      # data residual collapses
    assert (np.diff(h) <= 1e-6 * h[0]).mean() > 0.7   # mostly decreasing
    assert float(res.final_residual) == pytest.approx(h[-1])
    assert _rel(res.image, f) < 0.17


def test_fista_tv_denoises(setup):
    proj, f, y = setup
    noisy = y + 0.05 * float(jnp.abs(y).max()) * jax.random.normal(
        jax.random.PRNGKey(0), y.shape)
    x_plain = cgls(proj, noisy, n_iters=30).image
    x_tv = fista_tv(proj, noisy, n_iters=30, beta=2e-3).image
    assert float(tv_norm(x_tv)) < float(tv_norm(x_plain))
    assert _rel(x_tv, f) < _rel(x_plain, f)


def test_batched_solvers_match_per_sample(setup):
    """A stacked batch must reconstruct exactly like per-sample solves —
    the property the serving layer's packed dispatch relies on."""
    proj, f, y = setup
    y2 = jnp.stack([y, 0.5 * y])
    from repro.recon.fista_tv import power_iteration
    L = float(power_iteration(proj)) * 1.05
    for solver, kw in ((sirt, {}), (cgls, {}),
                       (fista_tv, {"beta": 2e-3, "L": L})):
        batched = solver(proj, y2, n_iters=8, **kw)
        assert batched.image.shape == (2,) + proj.vol_shape()
        assert batched.residual_history.shape == (2, 8)
        for i, yi in enumerate((y, 0.5 * y)):
            single = solver(proj, yi, n_iters=8, **kw)
            np.testing.assert_allclose(np.asarray(batched.image[i]),
                                       np.asarray(single.image),
                                       rtol=2e-5, atol=2e-6)


def test_data_consistency_refine_improves(setup):
    proj, f, y = setup
    mask = np.zeros(proj.sino_shape(), np.float32)
    mask[:20] = 1.0                     # 60 deg of 180
    mask = jnp.asarray(mask)
    x0 = proj.fbp(mask * y)
    xr, completed = complete_and_refine(proj, x0, y, mask, n_iters=25,
                                        beta=0.05)
    assert _rel(xr, f) < _rel(x0, f)
    # completion keeps measured views bit-exact
    np.testing.assert_allclose(np.asarray(completed[:20]), np.asarray(y[:20]),
                               rtol=0, atol=0)


def test_sirt_cone(setup):
    vol = VolumeGeometry(32, 32, 8)
    g = cone_beam(40, 16, 48, vol, sod=150.0, sdd=300.0,
                  pixel_width=2.0, pixel_height=2.0)
    proj = Projector(ProjectorSpec(g))
    f = jnp.zeros(vol.shape).at[12:20, 12:20, 2:6].set(0.02)
    y = proj(f)
    x = sirt(proj, y, n_iters=60).image
    assert _rel(x, f) < 0.35


def test_masked_sirt_limited_angle(setup):
    proj, f, y = setup
    mask = np.zeros(proj.sino_shape(), np.float32)
    mask[:20] = 1.0
    x = sirt(proj, y * mask, n_iters=60, mask=jnp.asarray(mask)).image
    assert _rel(x, f) < 0.8  # severely ill-posed (60 of 180 deg) but bounded

"""On-kernel modular beam: Pallas SF FP/BP matched pair + helical scans.

The modular pair (``kernels/fp_modular.py``) must

* agree with its jnp SF oracle (same frame math, no Pallas windowing) on
  helical and irregular trajectories — FP and BP;
* reduce *exactly* to the cone pair on axial circular trajectories
  (``cone_as_modular`` cross-checks, Pallas vs Pallas);
* reject tilted (non-axial) frames loudly on the kernel path while the ref
  backend falls back to the Joseph ray-marcher;
* batch by grid folding with bit-identical per-sample results;
* drive the iterative recon stack on a helical scan out of the box.

Adjoint dot-tests for the pair live in tests/test_adjoint.py.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Projector, VolumeGeometry, cone_beam, from_config,
                        helical_beam, modular_beam)
from repro.core.geometry import cone_as_modular
from repro.kernels import fp_cone, fp_modular, ops, ref, tune
from repro.recon import cgls, fista_tv, sirt


def _vol(nz=8):
    return VolumeGeometry(16, 16, nz)


def _helical(vol, na=8, nv=10, nu=24, n_turns=1.0, pitch=8.0):
    return helical_beam(n_turns, pitch, na, nv, nu, vol, sod=80.0, sdd=160.0,
                        pixel_width=2.0, pixel_height=2.0)


def _wobbly(vol, na=7, nv=10, nu=24, seed=3):
    """Irregular trajectory: non-uniform angles, per-view sod/sdd/source-z
    wobble, per-view in-plane + axial detector shifts, e_v flipped on every
    other view — the frame freedoms the fixed-geometry kernels can't
    express."""
    rng = np.random.default_rng(seed)
    ang = np.sort(rng.uniform(0, 2 * np.pi, na))
    sod = 80.0 + rng.uniform(-5, 5, na)
    sdd = 160.0 + rng.uniform(-10, 10, na)
    zsrc = rng.uniform(-4, 4, na)
    c, s = np.cos(ang), np.sin(ang)
    src = np.stack([sod * c, sod * s, zsrc], -1)
    eu = np.stack([-s, c, np.zeros(na)], -1)
    evz = np.where(np.arange(na) % 2 == 0, 1.0, -1.0)
    ev = np.stack([np.zeros(na), np.zeros(na), evz], -1)
    ctr = (np.stack([(sod - sdd) * c, (sod - sdd) * s, zsrc], -1)
           + rng.uniform(-3, 3, na)[:, None] * eu
           + rng.uniform(-3, 3, na)[:, None] * ev)
    return modular_beam(src, ctr, eu, ev, n_rows=nv, n_cols=nu, vol=vol,
                        pixel_width=2.0, pixel_height=2.0)


def _tilted(vol):
    g = _wobbly(vol)
    ev = np.asarray(g.det_v).copy()
    ev[:, 0] = 0.2
    ev /= np.linalg.norm(ev, axis=1, keepdims=True)
    return modular_beam(g.source_pos, g.det_center, g.det_u, ev,
                        g.n_rows, g.n_cols, vol, g.pixel_width,
                        g.pixel_height)


def _rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(
        size=shape).astype(np.float32))


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / jnp.maximum(
        jnp.linalg.norm(b), 1e-12))


# --------------------------------------------------------------------------- #
# Helical constructor + config round-trip
# --------------------------------------------------------------------------- #
def test_helical_frames_axial():
    g = _helical(_vol())
    assert g.geom_type == "modular"
    assert fp_modular.modular_frames_axial(g)
    src = np.asarray(g.source_pos)
    # source orbits at sod and translates pitch mm per turn, centered on z=0
    assert np.allclose(np.hypot(src[:, 0], src[:, 1]), 80.0, atol=1e-4)
    assert np.isclose(src[0, 2], -4.0, atol=1e-5)        # -span/2
    assert np.all(np.diff(src[:, 2]) > 0)
    # detector rides with the source: per-view frames stay orthonormal
    eu, ev = np.asarray(g.det_u), np.asarray(g.det_v)
    assert np.allclose(np.einsum("ai,ai->a", eu, ev), 0.0, atol=1e-6)
    assert np.allclose(np.linalg.norm(eu, axis=1), 1.0, atol=1e-6)


def test_helical_validation():
    with pytest.raises(ValueError):
        helical_beam(0.0, 8.0, 8, 4, 24, _vol(), sod=80.0, sdd=160.0)
    with pytest.raises(ValueError):
        helical_beam(1.0, -1.0, 8, 4, 24, _vol(), sod=80.0, sdd=160.0)


def test_helical_from_config_roundtrip():
    cfg = {"geom_type": "helical", "n_turns": 1.5, "pitch": 6.0,
           "n_angles": 10, "n_rows": 8, "n_cols": 24,
           "sod": 80.0, "sdd": 160.0, "pixel_width": 2.0,
           "pixel_height": 2.0, "z_start": -3.0,
           "volume": {"nx": 16, "ny": 16, "nz": 8}}
    g = from_config(json.loads(json.dumps(cfg)))       # survives file I/O
    direct = helical_beam(1.5, 6.0, 10, 8, 24, _vol(), sod=80.0, sdd=160.0,
                          pixel_width=2.0, pixel_height=2.0, z_start=-3.0)
    assert g.geom_type == "modular"
    assert g.key() == direct.key()


# --------------------------------------------------------------------------- #
# Kernel vs oracle, and modular <-> cone equivalence
# --------------------------------------------------------------------------- #
def test_sf_ref_matches_cone_ref_on_axial_trajectory():
    """cone_as_modular cross-check, oracle level: the modular SF reference
    must reproduce the cone SF reference on a circular axial scan."""
    v = _vol()
    gc = cone_beam(6, 10, 24, v, sod=80.0, sdd=160.0,
                   pixel_width=2.0, pixel_height=2.0)
    f = _rand(v.shape)
    y_cone = ref.forward(f, gc, "sf")
    y_mod = fp_modular.fp_modular_sf_ref(f, cone_as_modular(gc))
    assert _rel(y_mod, y_cone) < 2e-5


@pytest.mark.parametrize("geom_fn", [_helical, _wobbly])
def test_fp_kernel_matches_oracle(geom_fn):
    v = _vol()
    g = geom_fn(v)
    f = _rand(v.shape)
    y_pal = fp_modular.fp_modular_sf_pallas(f, g)
    y_ref = fp_modular.fp_modular_sf_ref(f, g)
    assert _rel(y_pal, y_ref) < 1e-4


@pytest.mark.parametrize("geom_fn", [_helical, _wobbly])
def test_bp_kernel_matches_oracle(geom_fn):
    v = _vol()
    g = geom_fn(v)
    y = _rand(g.sino_shape, seed=1)
    b_pal = fp_modular.bp_modular_sf_pallas(y, g)
    b_ref = fp_modular.bp_modular_sf_ref(y, g)
    assert _rel(b_pal, b_ref) < 1e-4


def test_cone_as_modular_pallas_cross_check():
    """The modular Pallas pair must agree with the cone Pallas pair on an
    axial circular trajectory — two independent kernels, same model."""
    v = _vol()
    gc = cone_beam(6, 10, 24, v, sod=80.0, sdd=160.0,
                   pixel_width=2.0, pixel_height=2.0)
    gm = cone_as_modular(gc)
    f = _rand(v.shape)
    assert _rel(fp_modular.fp_modular_sf_pallas(f, gm),
                fp_cone.fp_cone_sf_pallas(f, gc)) < 1e-4
    y = _rand(gc.sino_shape, seed=1)
    assert _rel(fp_modular.bp_modular_sf_pallas(y, gm),
                fp_cone.bp_cone_sf_pallas(y, gc)) < 1e-4


def test_tall_volume_sliding_z_window():
    """nz far larger than the kernel's axial window NZW: the z-window
    genuinely slides (not clamped to the volume) while the source itself
    translates in z — the regime unique to helical scans."""
    v = _vol(nz=24)
    g = helical_beam(1.0, 16.0, 6, 6, 24, v, sod=80.0, sdd=120.0,
                     pixel_width=2.0, pixel_height=1.0)
    f = _rand(v.shape)
    assert _rel(fp_modular.fp_modular_sf_pallas(f, g),
                fp_modular.fp_modular_sf_ref(f, g)) < 1e-4


# --------------------------------------------------------------------------- #
# Batched grid folding
# --------------------------------------------------------------------------- #
def test_batched_fold_matches_per_sample():
    v = _vol()
    g = _helical(v, na=6)
    B = 3
    fb = _rand((B,) + v.shape)
    yb = fp_modular.fp_modular_sf_pallas(fb, g)
    y_each = jnp.stack([fp_modular.fp_modular_sf_pallas(fb[i], g)
                        for i in range(B)])
    np.testing.assert_allclose(np.asarray(yb), np.asarray(y_each),
                               rtol=1e-6, atol=1e-6)
    qb = _rand((B,) + g.sino_shape, seed=1)
    bb = fp_modular.bp_modular_sf_pallas(qb, g)
    b_each = jnp.stack([fp_modular.bp_modular_sf_pallas(qb[i], g)
                        for i in range(B)])
    np.testing.assert_allclose(np.asarray(bb), np.asarray(b_each),
                               rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------- #
# Frame gating + dispatch
# --------------------------------------------------------------------------- #
def test_tilted_frames_rejected_on_kernel_path():
    v = _vol()
    gt = _tilted(v)
    assert not fp_modular.modular_frames_axial(gt)
    f = _rand(v.shape)
    with pytest.raises(NotImplementedError):
        fp_modular.fp_modular_sf_pallas(f, gt)
    with pytest.raises(NotImplementedError):
        fp_modular.bp_modular_sf_pallas(_rand(gt.sino_shape), gt)


def test_tilted_frames_ref_falls_back_to_joseph():
    v = _vol()
    gt = _tilted(v)
    f = _rand(v.shape)
    np.testing.assert_allclose(
        np.asarray(fp_modular.fp_modular_sf_ref(f, gt)),
        np.asarray(ref.fp_modular_joseph(f, gt)), rtol=1e-6, atol=1e-6)


def test_joseph_oracle_pair_matched_tilted():
    """bp_modular_joseph_ref is the exact adjoint of the Joseph FP — the
    advertised oracle pair for tilted frames the SF kernels don't cover."""
    v = _vol()
    gt = _tilted(v)
    f = _rand(v.shape)
    y = _rand(gt.sino_shape, seed=1)
    lhs = jnp.vdot(ref.fp_modular_joseph(f, gt), y)
    rhs = jnp.vdot(f, fp_modular.bp_modular_joseph_ref(y, gt))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4, (lhs, rhs)


def test_supports_gate_registered():
    entry = ops._KERNEL_TABLE[("modular", "sf")]
    assert entry.supports is fp_modular.modular_frames_axial
    assert entry.supports(_helical(_vol()))
    assert not entry.supports(_tilted(_vol()))
    # auto backend never selects an unsupported kernel (off-TPU it is ref
    # regardless; the gate is what protects the TPU path)
    assert not ops._use_pallas(_tilted(_vol()), "sf", "auto")


def test_source_inside_volume_not_axial():
    v = _vol()
    na = 4
    ang = np.linspace(0, 2 * np.pi, na, endpoint=False)
    c, s = np.cos(ang), np.sin(ang)
    src = np.stack([5.0 * c, 5.0 * s, np.zeros(na)], -1)   # inside radius
    ctr = np.stack([-100.0 * c, -100.0 * s, np.zeros(na)], -1)
    eu = np.stack([-s, c, np.zeros(na)], -1)
    ev = np.stack([np.zeros(na), np.zeros(na), np.ones(na)], -1)
    g = modular_beam(src, ctr, eu, ev, 4, 24, v)
    assert not fp_modular.modular_frames_axial(g)


def test_modular_shape_class_and_heuristics():
    g = _helical(_vol(), nv=10)
    key = tune.shape_class(g)
    assert key[0] == "modular"
    cfg = tune.heuristic_config(g)
    # modular tiles physical detector rows like the exact cone kernels:
    # small column tile, rows padded to the sublane multiple (not 128)
    assert cfg.bu == 8 and cfg.bv == 16


def test_joseph_oracle_quantitative_agreement():
    """SF and Joseph are different discretizations of the same integral —
    they must agree to a few percent on a smooth object (helical scan)."""
    v = _vol()
    g = _helical(v)
    x, y, z = np.meshgrid(np.linspace(-1, 1, v.nx), np.linspace(-1, 1, v.ny),
                          np.linspace(-1, 1, v.nz), indexing="ij")
    f = jnp.asarray(np.exp(-(x ** 2 + y ** 2 + z ** 2) / 0.18
                           ).astype(np.float32))
    y_sf = fp_modular.fp_modular_sf_ref(f, g)
    y_j = ref.fp_modular_joseph(f, g)
    assert _rel(y_sf, y_j) < 0.06


# --------------------------------------------------------------------------- #
# Projector + iterative recon on a helical scan, out of the box
# --------------------------------------------------------------------------- #
def test_projector_gradient_is_modular_bp():
    v = _vol()
    g = _helical(v, na=6)
    proj = Projector(g, "sf", backend="pallas")
    assert proj.model == "sf"                      # no joseph coercion left
    f = _rand(v.shape)
    y = _rand(g.sino_shape, seed=1)
    grad = jax.grad(lambda x: 0.5 * jnp.sum((proj(x) - y) ** 2))(f)
    expected = fp_modular.bp_modular_sf_pallas(proj(f) - y, g)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


def test_recon_helical_out_of_the_box():
    """sirt / cgls / fista_tv reconstruct a helical scan through the stock
    Projector (default backend) — the ROADMAP's scenario-diversity goal."""
    v = _vol()
    g = helical_beam(1.5, 6.0, 24, 10, 28, v, sod=80.0, sdd=160.0,
                     pixel_width=1.5, pixel_height=1.5)
    f = (jnp.zeros(v.shape).at[5:11, 5:11, 2:6].set(0.02)
         .at[8:13, 3:7, 3:5].set(0.03))
    proj = Projector(g)
    y = proj(f)
    err0 = float(jnp.linalg.norm(f))
    x_s = sirt(proj, y, n_iters=30).image
    assert float(jnp.linalg.norm(x_s - f)) < 0.5 * err0
    x_c = cgls(proj, y, n_iters=15).image
    assert float(jnp.linalg.norm(x_c - f)) < 0.35 * err0
    x_t = fista_tv(proj, y, n_iters=15, beta=1e-5).image
    assert float(jnp.linalg.norm(x_t - f)) < 0.6 * err0

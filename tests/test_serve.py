"""Continuous-batching server: slot recycling, per-slot positions, and
consistency of served tokens with offline greedy decoding."""
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Request, Server
from repro.models import model as MD


def _greedy_offline(cfg, params, prompt, max_new):
    cache = MD.init_cache(cfg, 1, 128)
    out = []
    for t in range(len(prompt) + max_new - 1):
        cur = prompt[t] if t < len(prompt) else out[-1]
        lg, cache = MD.decode_step(cfg, params, cache,
                                   jnp.asarray([cur], jnp.int32),
                                   jnp.asarray([t], jnp.int32))
        if t >= len(prompt) - 1:
            out.append(int(jnp.argmax(lg[0])))
    return out


def test_server_matches_offline_decode():
    cfg = configs.get_smoke("tinyllama_1_1b")
    srv = Server(cfg, slots=2, max_len=64, seed=0)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).tolist()
               for _ in range(3)]          # 3 requests > 2 slots: recycling
    for rid, p in enumerate(prompts):
        srv.submit(Request(rid, p, max_new=4))
    done = {r.rid: r for r in srv.run()}
    assert len(done) == 3
    for rid, p in enumerate(prompts):
        expect = _greedy_offline(cfg, srv.params, p, 4)
        assert done[rid].out == expect, (rid, done[rid].out, expect)


def test_server_staggered_positions():
    """A request admitted mid-flight must decode correctly from position 0
    while other slots are deep in their sequences (per-slot positions)."""
    cfg = configs.get_smoke("qwen3_0_6b")
    srv = Server(cfg, slots=2, max_len=64, seed=0)
    rng = np.random.default_rng(2)
    long_p = rng.integers(0, cfg.vocab_size, size=12).tolist()
    short_p = rng.integers(0, cfg.vocab_size, size=3).tolist()
    srv.submit(Request(0, long_p, max_new=3))
    srv.submit(Request(1, short_p, max_new=3))
    srv.submit(Request(2, short_p, max_new=3))   # admitted when 1 finishes
    done = {r.rid: r for r in srv.run()}
    assert done[1].out == done[2].out == _greedy_offline(
        cfg, srv.params, short_p, 3)

"""repro-lint rule tests: violating / clean / suppressed fixture per rule,
CLI behavior, and the tree-is-clean integration gate.

File-scoped rules (RL001-RL005) run on fixture files written under a tmp
root whose layout mirrors the paths each rule scopes to.  The
introspection rules (RL006/RL007) are tested against the real repo — a
fake incomplete registry entry for the negative case, the actual tree for
the positive one.
"""
from __future__ import annotations

import pathlib
import sys
import textwrap

import pytest

from repro.lint.engine import collect, run_rules
from repro.lint.rules import (ALL_RULES, accumulator, asserts, benchrows,
                              by_code, drift, hashing, registry, warmpath)
from repro.lint.__main__ import main as lint_main

REPO = pathlib.Path(__file__).resolve().parents[1]


def run_fixture(tmp_path, relpath, source, rule):
    p = tmp_path / relpath
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    project = collect([str(p)], tmp_path)
    return run_rules(project, [rule])


# --------------------------------------------------------------------- #
# RL001 — f32 accumulator policy
# --------------------------------------------------------------------- #
RL001_SRC = """\
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kern(a, b, o_ref):
        bad = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))
        good = jax.lax.dot_general(
            a, b, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        wrong = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.bfloat16)
        sup = jax.lax.dot_general(a, b, (((1,), (0,)), ((), ())))  # repro-lint: disable=RL001
        return bad, good, wrong, sup

    def run(x):
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.bfloat16),
        )(x)

    def run_ok(x):
        return pl.pallas_call(
            kern,
            out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        )(x)
"""


def test_rl001_fixture(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/kernels/fp_fix.py",
                        RL001_SRC, accumulator)
    lines = sorted(d.line for d in diags)
    msgs = " | ".join(d.message for d in diags)
    # missing pet, wrong pet, bf16 out_shape — suppressed + clean stay out
    if len(diags) != 3:
        raise AssertionError(f"want 3 RL001 diags, got {diags}")
    if "preferred_element_type" not in msgs or "out_shape" not in msgs:
        raise AssertionError(msgs)
    if lines != [6, 10, 17]:
        raise AssertionError(lines)


def test_rl001_out_of_scope(tmp_path):
    # same violations in flash.py (not fp_*) are by-design out of scope
    diags = run_fixture(tmp_path, "src/repro/kernels/flash.py",
                        RL001_SRC, accumulator)
    if diags:
        raise AssertionError(diags)


# --------------------------------------------------------------------- #
# RL002 — no bare assert
# --------------------------------------------------------------------- #
RL002_SRC = """\
    def f(x):
        assert x > 0, "bad"
        return x

    def g(x):
        if x <= 0:
            raise ValueError(f"x={x} must be positive")
        assert x < 9  # repro-lint: disable=RL002
        return x
"""


def test_rl002_fixture(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/util.py", RL002_SRC, asserts)
    if [d.line for d in diags] != [2]:
        raise AssertionError(diags)
    if "python -O" not in diags[0].message:
        raise AssertionError(diags[0].message)


def test_rl002_tests_out_of_scope(tmp_path):
    diags = run_fixture(tmp_path, "tests/test_x.py", RL002_SRC, asserts)
    if diags:
        raise AssertionError(diags)


# --------------------------------------------------------------------- #
# RL003 — compat drift firewall
# --------------------------------------------------------------------- #
RL003_SRC = """\
    import jax
    from repro import compat

    def save(tree, compiled):
        flat = jax.tree_util.tree_flatten_with_path(tree)
        cost = compiled.cost_analysis()
        ok = compat.tree_flatten_with_path(tree)
        sup = jax.tree_util.tree_map_with_path(str, tree)  # repro-lint: disable=RL003
        return flat, cost, ok, sup
"""


def test_rl003_fixture(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/runtime/save.py",
                        RL003_SRC, drift)
    if [d.line for d in diags] != [5, 6]:
        raise AssertionError(diags)
    if "compat.tree_flatten_with_path" not in diags[0].message:
        raise AssertionError(diags[0].message)
    if "cost_analysis_dict" not in diags[1].message:
        raise AssertionError(diags[1].message)


def test_rl003_forbidden_import(tmp_path):
    src = "from jax.experimental.shard_map import shard_map\n"
    diags = run_fixture(tmp_path, "src/repro/x.py", src, drift)
    if len(diags) != 1 or "compat.shard_map" not in diags[0].message:
        raise AssertionError(diags)


def test_rl003_compat_itself_exempt(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/compat.py", RL003_SRC, drift)
    if diags:
        raise AssertionError(diags)


# --------------------------------------------------------------------- #
# RL004 — hash stability
# --------------------------------------------------------------------- #
RL004_SRC = """\
    import json

    class Spec:
        def cache_key(self):
            a = json.dumps({"k": self.v})
            b = hash(self.v)
            for k, v in self.d.items():
                a += k
            ok1 = json.dumps(["geom", self.v], sort_keys=False)
            ok2 = dict(sorted(self.d.items()))
            ok3 = json.dumps(self.d, sort_keys=True)
            sup = id(self)  # repro-lint: disable=RL004
            return a, b, ok1, ok2, ok3, sup

        def unrelated(self):
            return repr(self.d)
"""


def test_rl004_fixture(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/core/spec.py",
                        RL004_SRC, hashing)
    lines = sorted(d.line for d in diags)
    # unsorted json.dumps(dict), hash(), unsorted .items(); the literal
    # list dumps / sorted items / sort_keys=True / suppressed id() pass;
    # repr in unrelated() is outside the identity-path closure
    if lines != [5, 6, 7]:
        raise AssertionError(diags)


def test_rl004_closure_follows_helpers(tmp_path):
    src = """\
        class Spec:
            def bucket_key(self):
                return self._mix()

            def _mix(self):
                return id(self)
    """
    diags = run_fixture(tmp_path, "src/repro/core/spec.py", src, hashing)
    if len(diags) != 1 or "id()" not in diags[0].message:
        raise AssertionError(diags)
    if "_mix" not in diags[0].message:
        raise AssertionError(diags[0].message)


# --------------------------------------------------------------------- #
# RL005 — CTServer warm path
# --------------------------------------------------------------------- #
RL005_SRC = """\
    import jax

    class CTServer:
        def warm(self, spec):
            return jax.jit(lambda x: x)

        def _executor(self, key):
            return jax.jit(lambda x: x)

        def _helper(self):
            return jax.jit(lambda x: x)

        def step(self):
            fn = self._executor("k")
            return fn(self._helper())

        def submit(self, req):
            f = jax.jit(lambda x: x)  # repro-lint: disable=RL005
            return f
"""


def test_rl005_fixture(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/launch/ct_serve.py",
                        RL005_SRC, warmpath)
    # only the jit inside _helper (reached from step) fires: warm/_executor
    # are the seam, the submit jit is suppressed
    if len(diags) != 1 or diags[0].line != 11:
        raise AssertionError(diags)
    if "_helper" not in diags[0].message:
        raise AssertionError(diags[0].message)


def test_rl005_other_files_out_of_scope(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/launch/other.py",
                        RL005_SRC, warmpath)
    if diags:
        raise AssertionError(diags)


# --------------------------------------------------------------------- #
# RL006 — registry completeness (introspects the real registry)
# --------------------------------------------------------------------- #
def _real_project():
    return collect(["src", "tests", "benchmarks"], REPO)


def test_rl006_real_registry_is_complete():
    diags = registry.check(_real_project())
    if diags:
        raise AssertionError([d.format() for d in diags])


def test_rl006_flags_incomplete_entry(monkeypatch):
    from repro.kernels import ops
    fake = ops._KernelEntry(fp=lambda *a: None, bp=None)
    monkeypatch.setitem(ops._KERNEL_TABLE, ("helical", "sf"), fake)
    diags = [d for d in registry.check(_real_project())
             if "helical" in d.message]
    msgs = " | ".join(d.message for d in diags)
    # no bp, no oracle, no tune branch, no adjoint coverage
    if len(diags) != 4:
        raise AssertionError(msgs)
    for want in ("matched BP", "reference oracle", "tune", "adjoint"):
        if want not in msgs:
            raise AssertionError(f"missing {want!r} in: {msgs}")


# --------------------------------------------------------------------- #
# RL007 — bench rows vs baseline vs ci.yml (real tree + negative)
# --------------------------------------------------------------------- #
def test_rl007_real_tree_consistent():
    diags = benchrows.check(_real_project())
    if diags:
        raise AssertionError([d.format() for d in diags])


def test_rl007_detects_drift(tmp_path, monkeypatch):
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    (bench / "__init__.py").write_text("")
    (bench / "check_regression.py").write_text(textwrap.dedent("""\
        import re
        GATE = re.compile(r"^kernel/(fp|bp)")
        SERVE_GATE = re.compile(r"^serve/")
        DIST_GATE = re.compile(r"^dist/")
        QUALITY_GATE = re.compile(r"^quality/")
        GATED_PREFIXES = ("kernel/", "serve/", "dist/", "quality/")
        def expected_rows(prefixes=()):
            return ["kernel/fp_old/pallas"]
    """))
    (bench / "bench_fix.py").write_text(textwrap.dedent("""\
        csv_rows = []
        def run():
            csv_rows.append(("kernel/bp_new/pallas", 1.0, "tag"))
            csv_rows.append(("recon/ungated", 1.0, "tag"))
    """))
    # the real benchmarks package is already imported by other tests;
    # force the tmp one to win for this check
    monkeypatch.delitem(sys.modules, "benchmarks", raising=False)
    monkeypatch.delitem(sys.modules, "benchmarks.check_regression",
                        raising=False)
    diags = benchrows.check(collect([str(bench)], tmp_path))
    monkeypatch.delitem(sys.modules, "benchmarks", raising=False)
    monkeypatch.delitem(sys.modules, "benchmarks.check_regression",
                        raising=False)
    msgs = " | ".join(d.message for d in diags)
    # new gated row not in baseline + stale baseline row never emitted
    if len(diags) != 2:
        raise AssertionError(msgs)
    if "kernel/bp_new/pallas" not in msgs \
            or "kernel/fp_old/pallas" not in msgs:
        raise AssertionError(msgs)


def test_rl007_fstring_rows_match():
    rx = benchrows._fstring_regex
    import ast as _ast
    node = _ast.parse('f"kernel/fp2d_b{B}/pallas"').body[0].value
    import re as _re
    if not _re.fullmatch(rx(node), "kernel/fp2d_b8/pallas"):
        raise AssertionError(rx(node))


# --------------------------------------------------------------------- #
# Engine: pragmas, parse errors, CLI
# --------------------------------------------------------------------- #
def test_parse_error_is_rl000(tmp_path):
    diags = run_fixture(tmp_path, "src/repro/broken.py",
                        "def f(:\n", asserts)
    if len(diags) != 1 or diags[0].code != "RL000":
        raise AssertionError(diags)


def test_pragma_inside_string_does_not_suppress(tmp_path):
    src = '''\
        def f(x):
            s = "# repro-lint: disable=RL002"
            assert x, s
            return s
    '''
    diags = run_fixture(tmp_path, "src/repro/u.py", src, asserts)
    if len(diags) != 1:
        raise AssertionError(diags)


def test_explain_known_and_unknown(capsys):
    if lint_main(["--explain", "RL004"]) != 0:
        raise AssertionError("explain RL004 should exit 0")
    out = capsys.readouterr().out
    if "content-stable" not in out:
        raise AssertionError(out)
    if lint_main(["--explain", "RL999"]) != 2:
        raise AssertionError("unknown code should exit 2")


def test_cli_exit_codes(tmp_path, capsys):
    bad = tmp_path / "src" / "repro" / "m.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("def f(x):\n    assert x\n")
    if lint_main([str(bad), "--root", str(tmp_path),
                  "--select", "RL002"]) != 1:
        raise AssertionError("violation should exit 1")
    if lint_main([str(tmp_path / "nope"), "--root", str(tmp_path)]) != 2:
        raise AssertionError("missing path should exit 2")
    bad.write_text("def f(x):\n    return x\n")
    if lint_main([str(bad), "--root", str(tmp_path),
                  "--select", "RL002"]) != 0:
        raise AssertionError("clean should exit 0")
    capsys.readouterr()


def test_every_rule_has_docs():
    for rule in ALL_RULES:
        for attr in ("CODE", "NAME", "EXPLAIN", "check"):
            if not hasattr(rule, attr):
                raise AssertionError(f"{rule} missing {attr}")
        if by_code(rule.CODE) is not rule:
            raise AssertionError(rule.CODE)
        if rule.CODE not in rule.EXPLAIN:
            raise AssertionError(f"{rule.CODE} EXPLAIN must name itself")


# --------------------------------------------------------------------- #
# The acceptance gate: the tree itself is clean
# --------------------------------------------------------------------- #
def test_tree_is_clean():
    project = collect(["src", "tests", "benchmarks"], REPO)
    diags = run_rules(project, ALL_RULES)
    if diags:
        raise AssertionError("\n".join(d.format() for d in diags))

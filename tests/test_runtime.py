"""Runtime substrate: checkpointing, fault handling, compression, pipelines."""
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import checkpoint as CKPT
from repro.runtime import compression
from repro.runtime.fault import FleetMonitor, HostStatus, Supervisor, plan_remesh


def _tree():
    return {"a": {"w": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "b": np.ones((5,), np.float32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 7, t, extra={"data": {"seed": 0, "step": 7}})
    restored, extra, step = CKPT.restore(str(tmp_path), t)
    assert step == 7 and extra["data"]["step"] == 7
    np.testing.assert_array_equal(restored["a"]["w"], t["a"]["w"])


def test_checkpoint_latest_and_gc(tmp_path):
    t = _tree()
    ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, t)
    ck.wait()
    assert CKPT.latest_step(str(tmp_path)) == 4
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2              # gc keeps last 2


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    t = _tree()
    CKPT.save(str(tmp_path), 1, t)
    bad = {"a": {"w": np.zeros((2, 2), np.float32)}, "b": t["b"]}
    with pytest.raises(ValueError):
        CKPT.restore(str(tmp_path), bad)


def test_checkpoint_atomic_under_partial_write(tmp_path):
    """A stale .tmp directory must never be visible as a restore point."""
    t = _tree()
    CKPT.save(str(tmp_path), 1, t)
    os.makedirs(tmp_path / "step_0000000002.tmp")   # simulated crash mid-save
    assert CKPT.latest_step(str(tmp_path)) == 1


def test_fleet_monitor_dead_and_straggler():
    mon = FleetMonitor(n_hosts=8, timeout_s=10.0, grace_steps=0)
    now = time.time()
    for h in range(8):
        dt = 1.0 if h != 3 else 5.0     # host 3 is 5x slower
        mon.heartbeat(HostStatus(h, step=100, step_time_s=dt, timestamp=now))
    assert mon.dead_hosts(now) == []
    assert mon.stragglers() == [3]
    assert mon.dead_hosts(now + 100) == list(range(8))


def test_plan_remesh_shrinks_data_axis():
    assert plan_remesh(512, model_axis=16, pods=2) == (2, 16, 16)
    assert plan_remesh(511, model_axis=16, pods=2) == (2, 8, 16)  # pow2 data
    assert plan_remesh(256, model_axis=16, pods=1) == (16, 16)
    assert plan_remesh(250, model_axis=16, pods=1) == (8, 16)
    assert plan_remesh(8, model_axis=16, pods=1) is None


def test_supervisor_restarts_and_resumes():
    calls = []

    def loop(start):
        calls.append(start)
        if len(calls) < 3:
            raise RuntimeError("injected")
        return 100

    def restore():
        return len(calls) * 10

    sup = Supervisor(loop, restore, max_restarts=5, backoff_s=0.0)
    assert sup.run() == 100
    assert calls == [0, 10, 20]        # resumed from 'checkpoints'


def test_supervisor_gives_up():
    def loop(start):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        Supervisor(loop, lambda: 0, max_restarts=2, backoff_s=0.0).run()


def test_compression_error_feedback_convergence():
    """1-bit EF SGD still minimizes a quadratic (residual carries info)."""
    A = jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)))
    Q = A @ A.T / 16 + 0.5 * jnp.eye(16)
    x = jnp.ones((16,)) * 5.0
    res = compression.init_state({"x": x})

    def grad(x):
        return {"x": Q @ x}

    lr = 0.05
    params = {"x": x}
    for _ in range(300):
        q, res = compression.compress(grad(params["x"]), res)
        params = {"x": params["x"] - lr * q["x"]}
    assert float(jnp.linalg.norm(params["x"])) < 0.3


def test_data_pipeline_determinism_and_sharding():
    from repro.core.geometry import VolumeGeometry, parallel_beam
    from repro.data.pipeline import CTDataPipeline
    vol = VolumeGeometry(16, 16, 1)
    g = parallel_beam(12, 1, 24, vol)
    p1 = CTDataPipeline(g, batch_size=4, seed=1, shard_index=0, shard_count=2)
    p2 = CTDataPipeline(g, batch_size=4, seed=1, shard_index=1, shard_count=2)
    a1, m1 = p1.batch(0)
    b1, _ = p1.batch(0)
    np.testing.assert_array_equal(a1, b1)          # deterministic
    a2, _ = p2.batch(0)
    assert not np.allclose(a1, a2)                 # disjoint shards
    # state_dict replay
    p1.step = 5
    st = p1.state_dict()
    p3 = CTDataPipeline(g, batch_size=4, seed=1, shard_index=0, shard_count=2)
    p3.load_state_dict(st)
    np.testing.assert_array_equal(p1.batch(p1.step)[0], p3.batch(p3.step)[0])


def test_token_pipeline_shards_and_learnable_structure():
    from repro.data.tokens import TokenPipeline
    tp = TokenPipeline(1000, 64, 8, seed=0)
    b = tp.batch(0)
    assert b.shape == (8, 64) and b.max() < 1000
    span = 64 // 16
    np.testing.assert_array_equal(b[:, span:2 * span], b[:, :span])
    tp2 = TokenPipeline(1000, 64, 8, seed=0, shard_index=1, shard_count=2)
    assert not np.array_equal(tp2.batch(0), b[:4])

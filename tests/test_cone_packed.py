"""Packed (lane-packed, axial pre-resample) cone pair: error bound + dispatch.

The packed pair approximates the exact cone SF model by pre-resampling
detector rows onto volume z-planes at the *central* magnification
(``fp_cone._z_overlap_cone_packed``), which turns the transaxial remainder
into the fan kernel and unlocks batch x n_rows lane packing.  These tests
pin the three contracts the ROADMAP item asks for:

* the packed-vs-exact sinogram error stays within the *documented* bound
  (``cone_packed_error_bound``) across a half-cone-angle sweep;
* the packed pair is itself exactly matched (adjoint dot test ~1e-6),
  including the lane-packed batched path;
* ``mode="auto"`` dispatches packed only under the tolerance gate and
  refuses geometries past it (falling back to the exact pair).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Projector, VolumeGeometry, cone_beam
from repro.kernels import fp_cone, ops, tune
from repro.kernels.tune import LANE, KernelConfig


def _geom(sod=200.0, nz=4, nv=4, nxy=16, dz=1.0, dv=2.0):
    vol = VolumeGeometry(nxy, nxy, nz, dz=dz)
    return cone_beam(6, nv, 24, vol, sod=sod, sdd=2.0 * sod,
                     pixel_width=2.0, pixel_height=dv)


def _blob_volume(vol, seed=0):
    """Smooth test volume (Gaussian blobs) — the regime packed mode targets."""
    rng = np.random.default_rng(seed)
    x, y, z = np.meshgrid(np.linspace(-1, 1, vol.nx),
                          np.linspace(-1, 1, vol.ny),
                          np.linspace(-1, 1, vol.nz), indexing="ij")
    f = np.zeros(vol.shape, np.float32)
    for _ in range(4):
        cx, cy, cz = rng.uniform(-0.5, 0.5, 3)
        w = rng.uniform(0.15, 0.4)
        f += np.exp(-((x - cx) ** 2 + (y - cy) ** 2 + (z - cz) ** 2)
                    / (2 * w * w)).astype(np.float32)
    return jnp.asarray(f)


# --------------------------------------------------------------------------- #
# Error bound
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("sod", [400.0, 200.0, 100.0, 60.0])
def test_packed_error_within_bound_over_cone_angles(sod):
    """Half-cone-angle sweep (sod shrinking at fixed z extent): the measured
    relative L2 error must stay under the documented per-geometry bound."""
    g = _geom(sod=sod)
    f = _blob_volume(g.vol)
    y_exact = fp_cone.fp_cone_sf_pallas(f, g)
    y_pack = fp_cone.fp_cone_packed(f, g)
    err = float(jnp.linalg.norm(y_pack - y_exact)
                / jnp.linalg.norm(y_exact))
    bound = fp_cone.cone_packed_error_bound(g)
    assert err <= bound, (err, bound)


def test_packed_error_and_bound_shrink_with_cone_angle():
    """Both the bound and the measured error are monotone in the half-cone
    angle, and the bound is first-order small (vanishes in the fan limit)."""
    errs, bounds = [], []
    for sod in (60.0, 120.0, 240.0, 480.0):
        g = _geom(sod=sod)
        f = _blob_volume(g.vol)
        y_exact = fp_cone.fp_cone_sf_pallas(f, g)
        y_pack = fp_cone.fp_cone_packed(f, g)
        errs.append(float(jnp.linalg.norm(y_pack - y_exact)
                          / jnp.linalg.norm(y_exact)))
        bounds.append(fp_cone.cone_packed_error_bound(g))
    assert all(b1 > b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert all(e1 >= e2 * 0.5 for e1, e2 in zip(errs, errs[1:]))  # ~monotone
    assert errs[-1] < errs[0]
    assert bounds[-1] < 0.2


def test_row_shift_scales_with_z_extent():
    shifts = [fp_cone.cone_packed_row_shift(_geom(nz=nz, nv=2 * nz))
              for nz in (2, 4, 8)]
    assert shifts[0] < shifts[1] < shifts[2]


# --------------------------------------------------------------------------- #
# Matched pair (adjoint) + batched path
# --------------------------------------------------------------------------- #
def test_packed_pair_adjoint_dot():
    g = _geom()
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    lhs = jnp.vdot(fp_cone.fp_cone_packed(f, g), y)
    rhs = jnp.vdot(f, fp_cone.bp_cone_packed(y, g))
    assert abs(lhs - rhs) / abs(lhs) < 2e-5


def test_packed_pair_adjoint_dot_batched():
    g = _geom()
    B = 3
    f = jax.random.normal(jax.random.PRNGKey(0), (B,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (B,) + g.sino_shape)
    lhs = jnp.vdot(fp_cone.fp_cone_packed(f, g), y)
    rhs = jnp.vdot(f, fp_cone.bp_cone_packed(y, g))
    assert abs(lhs - rhs) / abs(lhs) < 2e-5


def test_packed_batched_matches_per_sample():
    """The lane-packed batch fold is exactly the per-sample computation."""
    g = _geom()
    B = 3
    f = jax.random.normal(jax.random.PRNGKey(0), (B,) + g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), (B,) + g.sino_shape)
    np.testing.assert_allclose(
        np.asarray(fp_cone.fp_cone_packed(f, g)),
        np.stack([np.asarray(fp_cone.fp_cone_packed(f[i], g))
                  for i in range(B)]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(
        np.asarray(fp_cone.bp_cone_packed(y, g)),
        np.stack([np.asarray(fp_cone.bp_cone_packed(y[i], g))
                  for i in range(B)]), rtol=2e-4, atol=2e-4)


def test_packed_kernels_match_jnp_oracle():
    """Kernel-vs-oracle anchor: fp_cone_packed against the pure-jnp packed
    oracle, and bp_cone_packed against the oracle's exact linear transpose
    (jax.vjp of the oracle — no kernels involved on the oracle side)."""
    g = _geom()
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    np.testing.assert_allclose(np.asarray(fp_cone.fp_cone_packed(f, g)),
                               np.asarray(fp_cone.fp_cone_packed_ref(f, g)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fp_cone.bp_cone_packed(y, g)),
                               np.asarray(fp_cone.bp_cone_packed_ref(y, g)),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# Dispatch policy (mode="exact"|"packed"|"auto")
# --------------------------------------------------------------------------- #
def test_auto_dispatches_packed_under_tolerance():
    g = _geom(sod=400.0)
    assert tune.packed_cone_ok(g)
    assert ops.resolve_mode(g, backend="pallas") == "packed"
    # the dispatched op really is the packed kernel
    f = _blob_volume(g.vol)
    out = ops.forward_project(f, g, backend="pallas", mode="auto")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(fp_cone.fp_cone_packed(f, g)),
                               rtol=1e-5, atol=1e-5)


def test_auto_refuses_past_threshold():
    """A wide-cone geometry (row shift >> tolerance) must fall back to the
    exact pair under mode="auto"."""
    g = cone_beam(4, 16, 24, VolumeGeometry(16, 16, 16, dz=2.0),
                  sod=40.0, sdd=80.0, pixel_width=2.0, pixel_height=2.0)
    assert fp_cone.cone_packed_row_shift(g) > tune.packed_cone_tolerance()
    assert not tune.packed_cone_ok(g)
    assert ops.resolve_mode(g, backend="pallas") == "exact"
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    out = ops.forward_project(f, g, backend="pallas", mode="auto")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(fp_cone.fp_cone_sf_pallas(f, g)),
                               rtol=1e-5, atol=1e-5)


def test_tolerance_env_override(monkeypatch):
    g = _geom(sod=400.0)
    assert tune.packed_cone_ok(g)
    monkeypatch.setenv("REPRO_PACKED_CONE_TOL", "1e-9")
    assert not tune.packed_cone_ok(g)
    assert ops.resolve_mode(g, backend="pallas") == "exact"
    # a typo'd tolerance must be loud, not a silent fallback to the default
    monkeypatch.setenv("REPRO_PACKED_CONE_TOL", "0.1rows")
    with pytest.raises(ValueError):
        tune.packed_cone_tolerance()


def test_mode_packed_forces_packed_and_exact_forces_exact():
    g = _geom(sod=60.0)     # past nothing — just distinguishable numerics
    f = _blob_volume(g.vol)
    y_pack = ops.forward_project(f, g, backend="pallas", mode="packed")
    y_exact = ops.forward_project(f, g, backend="pallas", mode="exact")
    np.testing.assert_allclose(np.asarray(y_pack),
                               np.asarray(fp_cone.fp_cone_packed(f, g)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y_exact),
                               np.asarray(fp_cone.fp_cone_sf_pallas(f, g)),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(y_pack - y_exact))) > 0


def test_mode_validation_and_unavailable_packed():
    g = _geom()
    with pytest.raises(ValueError):
        ops.resolve_mode(g, mode="fast")
    with pytest.raises(ValueError):
        Projector(g, mode="fast")
    # no packed pair registered for parallel: forcing it must raise
    from repro.core import parallel_beam
    gp = parallel_beam(4, 2, 16, VolumeGeometry(8, 8, 2))
    with pytest.raises(NotImplementedError):
        ops.forward_project(jnp.zeros(gp.vol.shape), gp,
                            backend="pallas", mode="packed")
    # curved-detector cone: packed pre-resample is flat-only — explicit raise
    gc = cone_beam(4, 4, 16, VolumeGeometry(8, 8, 4), sod=200.0, sdd=400.0,
                   pixel_width=2.0, pixel_height=2.0, detector_type="curved")
    with pytest.raises(NotImplementedError):
        ops.forward_project(jnp.zeros(gc.vol.shape), gc,
                            backend="pallas", mode="packed")
    # off the pallas backend mode="auto" quietly stays exact (ref path)
    assert ops.resolve_mode(g, backend="ref", mode="auto") == "exact"


def test_projector_mode_plumbing_and_gradients():
    """mode= flows Projector -> ops; the packed pair is wired as a matched
    custom_vjp pair, so the gradient of the data term is the packed BP."""
    g = _geom(sod=400.0)
    proj = Projector(g, backend="pallas", mode="packed")
    f = _blob_volume(g.vol)
    y = fp_cone.fp_cone_packed(f, g)
    np.testing.assert_allclose(np.asarray(proj(f)), np.asarray(y),
                               rtol=1e-5, atol=1e-5)
    meas = jnp.zeros_like(y)
    grad = jax.grad(lambda x: 0.5 * jnp.sum((proj(x) - meas) ** 2))(f)
    expect = fp_cone.bp_cone_packed(y, g)
    np.testing.assert_allclose(np.asarray(grad), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# Tuning integration
# --------------------------------------------------------------------------- #
def test_packed_shape_class_is_its_own_regime():
    g = _geom()
    exact_key = tune.shape_class(g, packed=False)
    packed_key = tune.shape_class(g, packed=True)
    assert exact_key != packed_key
    assert packed_key[0] == "cone-packed"


def test_packed_heuristic_lane_packs():
    """Packed cone tunes like fan: full 128-lane tile, not the physical-row
    tile of the exact cone kernel."""
    g = _geom(nv=4)
    exact = tune.heuristic_config(g)
    packed = tune.heuristic_config(g, packed=True)
    assert exact.bv < LANE          # exact tiles physical rows (nv=4 -> 8)
    assert packed.bv == LANE


def test_packed_respects_pinned_config():
    g = _geom()
    f = _blob_volume(g.vol)
    base = fp_cone.fp_cone_packed(f, g)
    pinned = fp_cone.fp_cone_packed(f, g, config=KernelConfig(bu=8, ba=2))
    np.testing.assert_allclose(np.asarray(pinned), np.asarray(base),
                               rtol=2e-4, atol=2e-4)


def test_packed_matches_fan_limit():
    """Thin central-slice geometry (nz=1, z=0): the packed path agrees with
    the exact cone path up to the voxel's own *thickness* magnification
    spread (first order in dz·R/sod — well inside the documented bound)."""
    vol = VolumeGeometry(16, 16, 1, dz=1.0)
    g = cone_beam(6, 1, 24, vol, sod=400.0, sdd=800.0,
                  pixel_width=2.0, pixel_height=2.0)
    f = jax.random.normal(jax.random.PRNGKey(0), vol.shape)
    y_exact = fp_cone.fp_cone_sf_pallas(f, g)
    y_pack = fp_cone.fp_cone_packed(f, g)
    err = float(jnp.linalg.norm(y_pack - y_exact) / jnp.linalg.norm(y_exact))
    assert err <= fp_cone.cone_packed_error_bound(g)
    assert err < 0.02

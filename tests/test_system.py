"""System-level behaviour: the public API composes end to end (the paper's
Listing-1 usage pattern), batching, jit caching, and config-file driving."""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Projector, VolumeGeometry, back_project, fbp,
                        forward_project, from_config, parallel_beam)


def test_listing1_usage_pattern():
    """The paper's PyTorch snippet, in JAX: projector inside a model."""
    vol = VolumeGeometry(24, 24, 1)
    geom = parallel_beam(12, 1, 36, vol)
    proj = Projector(geom)

    def model(theta, measured):
        # trivial 'network': volume is the parameter; loss is Ax - y
        return jnp.mean(jnp.square(proj(theta) - measured))

    theta = jnp.zeros(vol.shape)
    y = jnp.ones(geom.sino_shape)
    g = jax.grad(model)(theta, y)
    assert g.shape == vol.shape
    assert float(jnp.abs(g).sum()) > 0


def test_batched_projection():
    vol = VolumeGeometry(16, 16, 2)
    geom = parallel_beam(6, 2, 24, vol)
    f = jax.random.normal(jax.random.PRNGKey(0), (3,) + vol.shape)
    sino = forward_project(f, geom)
    assert sino.shape == (3,) + geom.sino_shape
    one = forward_project(f[1], geom)
    np.testing.assert_allclose(np.asarray(sino[1]), np.asarray(one),
                               rtol=1e-5, atol=1e-6)
    vols = back_project(sino, geom)
    assert vols.shape == f.shape


def test_op_cache_reuse():
    from repro.kernels.ops import get_ops
    vol = VolumeGeometry(16, 16, 2)
    geom = parallel_beam(6, 2, 24, vol)
    fp1, bp1 = get_ops(geom, "sf", "ref")
    fp2, bp2 = get_ops(geom, "sf", "ref")
    assert fp1 is fp2 and bp1 is bp2   # lru-cached per geometry key


def test_config_file_driving(tmp_path):
    cfg = {"geom_type": "parallel", "n_angles": 8, "n_rows": 2, "n_cols": 24,
           "volume": {"nx": 16, "ny": 16, "nz": 2}}
    p = tmp_path / "geom.json"
    p.write_text(json.dumps(cfg))
    geom = from_config(json.loads(p.read_text()))
    f = jnp.ones(geom.vol.shape)
    rec = fbp(forward_project(f, geom), geom)
    assert rec.shape == geom.vol.shape


def test_jit_compatible_end_to_end():
    vol = VolumeGeometry(16, 16, 1)
    geom = parallel_beam(8, 1, 24, vol)
    proj = Projector(geom)

    @jax.jit
    def recon_loss(x, y):
        return 0.5 * jnp.sum((proj(x) - y) ** 2)

    x = jnp.ones(vol.shape)
    y = proj(x)
    assert float(recon_loss(x, y)) < 1e-6

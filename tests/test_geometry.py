import numpy as np
import pytest

from repro.core.geometry import (CTGeometry, VolumeGeometry, cone_beam,
                                 fan_beam, from_config, helical_beam,
                                 parallel_beam)


def test_volume_coords_centered():
    v = VolumeGeometry(8, 8, 4, dx=2.0, dy=2.0, dz=1.0, offset_x=3.0)
    assert np.isclose(v.x_coords().mean(), 3.0)
    assert np.isclose(v.y_coords().mean(), 0.0)
    assert np.isclose(np.diff(v.x_coords())[0], 2.0)


def test_volume_validation():
    with pytest.raises(ValueError):
        VolumeGeometry(0, 8, 8)
    with pytest.raises(ValueError):
        VolumeGeometry(8, 8, 8, dx=1.0, dy=2.0)  # non-square in-plane


def test_cone_validation():
    v = VolumeGeometry(32, 32, 8)
    with pytest.raises(ValueError):
        cone_beam(10, 8, 48, v, sod=400.0, sdd=300.0)  # sdd < sod
    with pytest.raises(ValueError):
        cone_beam(10, 8, 48, v, sod=10.0, sdd=300.0)   # source inside volume


def test_angles_subset_and_nonequispaced():
    v = VolumeGeometry(16, 16, 2)
    ang = np.sort(np.random.default_rng(0).uniform(0, np.pi, 12))
    g = parallel_beam(12, 2, 24, v, angles=ang)
    sub = g.subset([0, 3, 5])
    assert sub.n_angles == 3
    assert np.allclose(sub.angles_array(), ang[[0, 3, 5]], atol=1e-6)


def test_fan_validation():
    v = VolumeGeometry(32, 32, 2)
    with pytest.raises(ValueError):
        fan_beam(10, 2, 48, v, sod=400.0, sdd=300.0)   # sdd < sod
    with pytest.raises(ValueError):
        fan_beam(10, 2, 48, v, sod=10.0, sdd=300.0)    # source inside volume
    with pytest.raises(ValueError):
        fan_beam(10, 2, 48, v, sod=100.0, sdd=200.0, detector_type="bent")
    with pytest.raises(ValueError):
        # curved arc spanning >= pi/2 half fan angle
        fan_beam(10, 2, 480, v, sod=100.0, sdd=200.0, pixel_width=2.0,
                 detector_type="curved")
    g = fan_beam(10, 2, 48, v, sod=100.0, sdd=200.0, detector_type="curved")
    assert g.magnification == 2.0
    sub = g.subset([1, 4])
    assert sub.n_angles == 2 and sub.geom_type == "fan"


def test_from_config_roundtrip():
    cfg = {"geom_type": "parallel", "n_angles": 6, "n_rows": 2, "n_cols": 24,
           "volume": {"nx": 16, "ny": 16, "nz": 2}}
    g = from_config(cfg)
    assert g.sino_shape == (6, 2, 24)
    assert g.key()  # hashable static key


def test_from_config_fan_roundtrip():
    """Regression: from_config used to raise for fan dicts."""
    cfg = {"geom_type": "fan", "n_angles": 8, "n_rows": 2, "n_cols": 32,
           "sod": 100.0, "sdd": 250.0, "pixel_width": 2.0,
           "detector_type": "curved",
           "volume": {"nx": 16, "ny": 16, "nz": 2}}
    g = from_config(cfg)
    assert g.geom_type == "fan" and g.detector_type == "curved"
    assert g.sino_shape == (8, 2, 32)
    assert g.sod == 100.0 and g.sdd == 250.0
    assert g.key()


def test_from_config_helical():
    """'helical' configs build modular frames (compact n_turns/pitch
    spelling) identical to the direct constructor."""
    v = {"nx": 16, "ny": 16, "nz": 4}
    cfg = {"geom_type": "helical", "n_turns": 2.0, "pitch": 4.0,
           "n_angles": 12, "n_rows": 4, "n_cols": 24,
           "sod": 100.0, "sdd": 200.0, "volume": v}
    g = from_config(cfg)
    assert g.geom_type == "modular"
    assert g.key() == helical_beam(2.0, 4.0, 12, 4, 24,
                                   VolumeGeometry(16, 16, 4),
                                   sod=100.0, sdd=200.0).key()
    src = np.asarray(g.source_pos)
    # two turns: the azimuth wraps twice, z sweeps n_turns * pitch
    assert np.isclose(src[-1, 2] - src[0, 2], 2.0 * 4.0 * (11 / 12))


def test_modular_requires_vectors():
    v = VolumeGeometry(16, 16, 2)
    src = np.zeros((4, 3))
    with pytest.raises(ValueError):
        CTGeometry("modular", v, 4, 2, 24, angles=(0.0,) * 4, source_pos=src,
                   det_center=None, det_u=None, det_v=None)


def test_footprint_bounds_static():
    v = VolumeGeometry(32, 32, 8)
    g = cone_beam(10, 8, 48, v, sod=100.0, sdd=200.0)
    assert g.max_footprint_cols() >= 2
    assert g.max_footprint_rows() >= 2

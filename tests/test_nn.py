"""Direct unit tests for the recon networks and the paper-§4 inference
pieces: CT-Net / U-Net shapes+dtypes, gradients through the projector,
EMA averaging, and the data-consistency refinement contract."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.geometry import VolumeGeometry, parallel_beam
from repro.core.projector import Projector
from repro.core.spec import ProjectorSpec
from repro.nn.ctnet import ctnet_apply, ctnet_init
from repro.nn.unet import unet_apply, unet_init
from repro.optim import (EmaState, ema_decay_schedule, ema_init, ema_params,
                         ema_update)
from repro.recon.completion import (complete_and_refine,
                                    data_consistency_refine,
                                    projection_residual)


@pytest.fixture(scope="module")
def small_proj():
    geom = parallel_beam(18, 1, 18, VolumeGeometry(12, 12, 1))
    return Projector(ProjectorSpec(geom))


# --------------------------------------------------------------------------- #
# CT-Net (sinogram completion)
# --------------------------------------------------------------------------- #
def test_ctnet_shapes_and_passthrough():
    key = jax.random.PRNGKey(0)
    p = ctnet_init(key, base=8, depth=2)
    sino = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, 16))
    mask = (jnp.arange(12) < 8).astype(jnp.float32)
    mask2d = mask[None, :, None] * jnp.ones((2, 1, 16))
    out = ctnet_apply(p, sino * mask2d, mask2d)
    assert out.shape == (2, 12, 16)
    assert out.dtype == jnp.float32
    # measured views are passed through exactly, not re-predicted
    np.testing.assert_allclose(np.asarray(out[:, :8]),
                               np.asarray(sino[:, :8]), rtol=1e-6)
    # missing views get *some* prediction (not the zeroed input)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------- #
# U-Net (image refinement)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("ch", [1, 3])
def test_unet_shapes_multichannel(ch):
    p = unet_init(jax.random.PRNGKey(0), base=8, levels=2,
                  in_ch=ch, out_ch=ch)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, ch))
    y = unet_apply(p, x)
    assert y.shape == x.shape and y.dtype == x.dtype
    # zero-initialized output head => the net is exactly the identity at
    # init (the residual path), which is what keeps training stable
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-6)


def test_unet_grad_through_projector_finite(small_proj):
    """The paper's core claim at unit scale: d(loss)/d(params) through
    A(unet(x)) exists and is finite everywhere."""
    proj = small_proj
    p = unet_init(jax.random.PRNGKey(0), base=8, levels=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 12, 1)) * 0.02
    y_meas = proj(unet_apply(p, x)[0]) + 0.1

    def loss(params):
        return jnp.mean(jnp.square(proj(unet_apply(params, x)[0]) - y_meas))

    g = jax.grad(loss)(p)
    leaves = jax.tree.leaves(g)
    assert leaves and all(np.isfinite(np.asarray(l)).all() for l in leaves)
    # the projector must actually transmit gradient to the weights
    assert any(float(jnp.abs(l).max()) > 0 for l in leaves)


# --------------------------------------------------------------------------- #
# EMA
# --------------------------------------------------------------------------- #
def test_ema_converges_to_constant_stream():
    params = {"w": jnp.zeros((3,)), "b": jnp.zeros(())}
    target = {"w": jnp.full((3,), 2.5), "b": jnp.asarray(-1.0)}
    ema = ema_init(params)
    for _ in range(400):
        ema = ema_update(ema, target, decay=0.99, warmup=10)
    assert int(ema.step) == 400
    for leaf, ref in zip(jax.tree.leaves(ema_params(ema)),
                         jax.tree.leaves(target)):
        np.testing.assert_allclose(np.asarray(leaf), np.asarray(ref),
                                   atol=1e-2)


def test_ema_warmup_tracks_faster_than_fixed_decay():
    """Early on, the warmed-up decay must track the stream much faster than
    the asymptotic decay would (the whole point of the warmup)."""
    d5 = float(ema_decay_schedule(jnp.asarray(5), 0.999, warmup=10))
    assert d5 < 0.5          # (1+5)/(10+5) = 0.4, nowhere near 0.999
    d_inf = float(ema_decay_schedule(jnp.asarray(10_000), 0.999, warmup=10))
    assert d_inf == pytest.approx(0.999)


def test_ema_validation():
    ema = ema_init({"w": jnp.zeros(())})
    with pytest.raises(ValueError):
        ema_update(ema, {"w": jnp.ones(())}, decay=1.0)
    with pytest.raises(ValueError):
        ema_update(ema, {"w": jnp.ones(())}, warmup=0)


def test_ema_update_is_jittable():
    params = {"w": jnp.ones((4,))}
    ema = ema_init(params)
    step = jax.jit(lambda e, p: ema_update(e, p, decay=0.9, warmup=2))
    ema = step(ema, {"w": jnp.full((4,), 3.0)})
    assert isinstance(ema, EmaState) and int(ema.step) == 1


# --------------------------------------------------------------------------- #
# Data-consistency refinement + residual
# --------------------------------------------------------------------------- #
def test_projection_residual_zero_on_exact_data(small_proj):
    proj = small_proj
    x = jnp.ones(proj.spec.geom.vol.shape) * 0.02
    y = proj(x)
    assert float(projection_residual(proj, x, y)) < 1e-5
    assert float(projection_residual(proj, 0.0 * x, y)) == pytest.approx(1.0)


def test_refinement_reduces_dc_residual(small_proj):
    proj = small_proj
    geom = proj.spec.geom
    rng = np.random.default_rng(0)
    gt = jnp.asarray(rng.random(geom.vol.shape), jnp.float32) * 0.02
    y = proj(gt)
    mask = (jnp.arange(geom.n_angles) % 2 == 0).astype(jnp.float32)
    m3 = mask[:, None, None]
    x_net = gt + jnp.asarray(rng.normal(size=geom.vol.shape),
                             jnp.float32) * 0.004
    xr, completed = complete_and_refine(proj, x_net, y, m3,
                                        n_iters=25, beta=0.05)
    r_net = float(projection_residual(proj, x_net, y, m3))
    r_ref = float(projection_residual(proj, xr, y, m3))
    assert r_ref < r_net
    # completed sinogram keeps the measured views verbatim
    np.testing.assert_allclose(np.asarray(completed * m3),
                               np.asarray(y * m3), rtol=1e-5)


def test_refine_beta_limit_returns_prior(small_proj):
    """beta -> large means 'trust the network': the solution stays at
    x_net."""
    proj = small_proj
    gt = jnp.ones(proj.spec.geom.vol.shape) * 0.02
    y = proj(gt)
    m3 = jnp.ones((proj.spec.geom.n_angles, 1, 1))
    x_net = gt * 0.5
    xr = data_consistency_refine(proj, x_net, y, m3, n_iters=10, beta=1e6)
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x_net), atol=1e-4)

"""System invariants as hypothesis property tests (beyond the adjoint suite):
linearity, view-subset consistency, batching consistency, rotation symmetry,
optimizer/schedule invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Projector, VolumeGeometry, fan_beam, parallel_beam)

# hypothesis strategy over geometry families: parallel + fan (flat/curved)
GEOM_KINDS = st.sampled_from(["parallel", "fan-flat", "fan-curved"])


def _geom(na=8, seed=0, kind="parallel"):
    vol = VolumeGeometry(16, 16, 4)
    rng = np.random.default_rng(seed)
    ang = np.sort(rng.uniform(0, np.pi, na))
    if kind == "parallel":
        return parallel_beam(na, 4, 24, vol, angles=ang)
    det = "curved" if kind == "fan-curved" else "flat"
    return fan_beam(na, 4, 24, vol, sod=80.0, sdd=160.0, pixel_width=2.0,
                    angles=ang, detector_type=det)


@settings(max_examples=8, deadline=None)
@given(a=st.floats(-3.0, 3.0), b=st.floats(-3.0, 3.0),
       seed=st.integers(0, 50), kind=GEOM_KINDS)
def test_projector_linearity(a, b, seed, kind):
    g = _geom(seed=seed, kind=kind)
    proj = Projector(g, "sf")
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(kx, g.vol.shape)
    y = jax.random.normal(ky, g.vol.shape)
    lhs = proj(a * x + b * y)
    rhs = a * proj(x) + b * proj(y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50), k=st.integers(1, 6), kind=GEOM_KINDS)
def test_view_subset_consistency(seed, k, kind):
    """Projecting with geometry.subset(idx) == slicing the full sinogram —
    the invariant behind limited-angle/few-view augmentation and the
    distributed angle sharding."""
    g = _geom(na=8, seed=seed, kind=kind)
    idx = np.sort(np.random.default_rng(seed).choice(8, size=k, replace=False))
    sub = g.subset(idx)
    x = jax.random.normal(jax.random.PRNGKey(seed), g.vol.shape)
    full = Projector(g, "sf")(x)
    part = Projector(sub, "sf")(x)
    np.testing.assert_allclose(np.asarray(part), np.asarray(full[idx]),
                               rtol=1e-5, atol=1e-5)


def test_rotation_symmetry_radially_symmetric_object():
    """A radially symmetric phantom projects identically at every angle."""
    vol = VolumeGeometry(32, 32, 2)
    g = parallel_beam(12, 2, 48, vol)
    xs = vol.x_coords()
    X, Y = np.meshgrid(xs, vol.y_coords(), indexing="ij")
    f = np.exp(-(X ** 2 + Y ** 2) / 40.0).astype(np.float32)
    f = jnp.asarray(np.repeat(f[:, :, None], 2, 2))
    sino = np.asarray(Projector(g, "sf")(f))
    spread = np.abs(sino - sino.mean(axis=0)).max()
    assert spread < 6e-3 * sino.max()


def test_backprojection_of_uniform_sino_is_smooth_interior():
    """A^T(1) is strictly positive over the interior FOV (sanity for SIRT's
    normalization vectors)."""
    g = _geom()
    col = Projector(g, "sf").T(jnp.ones(g.sino_shape))
    interior = np.asarray(col)[4:12, 4:12, 1:3]
    assert interior.min() > 0


@settings(max_examples=10, deadline=None)
@given(lr=st.floats(1e-5, 1e-1), steps=st.integers(1, 50))
def test_warmup_cosine_bounds(lr, steps):
    from repro.optim import warmup_cosine
    f = warmup_cosine(lr, 10, 100, alpha=0.1)
    v = float(f(jnp.asarray(steps)))
    assert 0.0 <= v <= lr * (1 + 1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100))
def test_clip_by_global_norm_bound(seed):
    from repro.optim import clip_by_global_norm
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (7, 3)) * 100}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped))))
    assert total <= 1.0 + 1e-4


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 30))
def test_moe_gather_no_overflow_matches_dense(seed):
    """When every expert stays under capacity, gather == dense exactly."""
    from repro import configs
    from repro.models import model as MD, moe as MOE
    cfg = configs.get_smoke("olmoe_1b_7b")
    p = MD.init_params(cfg, jax.random.PRNGKey(seed))
    lp = jax.tree.map(lambda a: a[0], p["layers"])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, cfg.d_model)) * 0.1
    cd = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl="dense"))
    cg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl="gather"))
    yd, _ = MOE.moe_apply(lp, x, cd)
    yg, _ = MOE.moe_apply(lp, x, cg)
    # S=16, E=4, k=2 -> C = 10 >= worst-case per-expert load 16*2/4... not
    # guaranteed; tolerate capacity drops on <= 20% of tokens.
    diff = jnp.abs(yd - yg).max(axis=-1)
    frac_bad = float((diff > 1e-3 * float(jnp.abs(yd).max())).mean())
    assert frac_bad <= 0.25

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with '-m \"not slow\"')")
    # keep smoke tests on the single real device; the dry-run sets its own
    # XLA_FLAGS before importing jax (see launch/dryrun.py)
    assert jax.device_count() >= 1

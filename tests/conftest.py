import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_tune_cache(tmp_path, monkeypatch):
    """Point the autotune disk cache at a per-test path so a developer's
    real ~/.cache/repro/tune.json can't change kernel configs under tests
    (tests that exercise persistence explicitly override this)."""
    monkeypatch.setenv("REPRO_TUNE_CACHE_PATH", str(tmp_path / "tune.json"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with '-m \"not slow\"')")
    # keep smoke tests on the single real device; the dry-run sets its own
    # XLA_FLAGS before importing jax (see launch/dryrun.py)
    assert jax.device_count() >= 1

"""Per-architecture smoke tests: reduced same-family config, one forward +
train step + decode step on CPU; asserts shapes and no NaNs (assignment
requirement)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import model as MD
from repro.optim import adamw, constant


def _batch(cfg, B=2, S=64, key=0):
    k = jax.random.PRNGKey(key)
    if cfg.n_codebooks > 1:
        toks = jax.random.randint(k, (B, cfg.n_codebooks, S), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.vision_tokens:
        batch["vision_embeds"] = 0.01 * jax.random.normal(
            k, (B, cfg.vision_tokens, cfg.d_model))
        if cfg.rope == "mrope":
            St = S + cfg.vision_tokens
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(St)[None, None], (3, B, St))
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_train_step(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), grad_accum=1)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))
    step = make_train_step(cfg, opt)
    state = opt.init(params)
    batch = _batch(cfg)
    params, state, m = jax.jit(step)(params, state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    for leaf in jax.tree.leaves(params):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_decode_shapes(arch):
    cfg = configs.get_smoke(arch)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    cache = MD.init_cache(cfg, B, S)
    serve = make_serve_step(cfg)
    tok = (jnp.zeros((B, cfg.n_codebooks), jnp.int32) if cfg.n_codebooks > 1
           else jnp.zeros((B,), jnp.int32))
    nxt, lg, cache = jax.jit(serve)(params, cache, tok, jnp.asarray(0, jnp.int32))
    if cfg.n_codebooks > 1:
        assert lg.shape == (B, cfg.n_codebooks, cfg.vocab_size)
    else:
        assert lg.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(lg).any())
    assert nxt.dtype == jnp.int32


def test_decode_matches_forward():
    """Greedy decode logits at position t == training-forward logits at t
    (consistency between the two attention paths)."""
    cfg = configs.get_smoke("tinyllama_1_1b")
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    x, _ = MD.forward(cfg, params, toks)
    full_logits = MD.logits_fn(cfg, params, x)
    cache = MD.init_cache(cfg, B, S)
    for t in range(S):
        lg, cache = MD.decode_step(cfg, params, cache, toks[:, t],
                                   jnp.asarray(t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, t]),
                                   rtol=3e-2, atol=3e-2)


def test_mamba_decode_matches_forward():
    cfg = configs.get_smoke("falcon_mamba_7b")
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    x, _ = MD.forward(cfg, params, toks)
    full_logits = MD.logits_fn(cfg, params, x)
    cache = MD.init_cache(cfg, B, S)
    for t in range(S):
        lg, cache = MD.decode_step(cfg, params, cache, toks[:, t],
                                   jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, -1]),
                               rtol=5e-2, atol=5e-2)


def test_loss_decreases_smoke_training():
    cfg = dataclasses.replace(configs.get_smoke("qwen3_0_6b"), grad_accum=1)
    params = MD.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(3e-3))
    state = opt.init(params)
    step = jax.jit(make_train_step(cfg, opt))
    batch = _batch(cfg, B=4, S=64)     # fixed batch: must overfit
    losses = []
    for _ in range(15):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_param_counts_sane():
    approx = {"tinyllama_1_1b": 1.1e9, "qwen3_0_6b": 0.6e9,
              "nemotron_4_340b": 340e9, "grok_1_314b": 314e9,
              "falcon_mamba_7b": 7e9, "olmoe_1b_7b": 7e9,
              "starcoder2_3b": 3e9, "hymba_1_5b": 1.5e9,
              "qwen2_vl_72b": 72e9, "musicgen_large": 3.3e9}
    for arch, expect in approx.items():
        n = configs.get(arch).n_params()
        assert 0.5 * expect < n < 1.8 * expect, (arch, n, expect)

"""KernelConfig registry / heuristics / autotune plumbing + the op cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Projector, VolumeGeometry, parallel_beam
from repro.kernels import ops, ref, tune
from repro.kernels.tune import KernelConfig


@pytest.fixture(autouse=True)
def _clean_registry():
    tune.clear()
    yield
    tune.clear()


def _geom(**kw):
    return parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2), **kw)


def test_config_validation():
    with pytest.raises(ValueError):
        KernelConfig(bu=0)
    with pytest.raises(ValueError):
        KernelConfig(bv=100)          # not a sublane multiple
    with pytest.raises(ValueError):
        KernelConfig(bs=0)            # stripe-reuse factor must be >= 1
    c = KernelConfig(bu=8, ba=2)
    assert c.replace(ba=4).ba == 4 and c.ba == 2
    assert c.bs == 1                  # stripe reuse off by default
    assert c.replace(bs=4).bs == 4


def test_candidates_sweep_stripe_reuse():
    """The autotune candidate grid includes bs > 1 BP stripe-blocking
    entries."""
    cand = list(tune.default_candidates(_geom()))
    assert {c.bs for c in cand} >= {1, 2, 4}


def test_heuristic_defaults_off_tpu():
    cfg = tune.get_config(_geom())
    assert cfg.bv % 128 == 0
    if jax.default_backend() != "tpu":
        assert cfg.ba == 1 and cfg.bab == 1   # interpret mode: minimal programs


def test_shape_class_buckets_not_exact_values():
    g1 = _geom()
    g2 = parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2),
                       angles=np.linspace(0.1, 2.0, 6))
    assert tune.shape_class(g1) == tune.shape_class(g2)
    g3 = parallel_beam(6, 2, 500, VolumeGeometry(16, 16, 2))
    assert tune.shape_class(g1) != tune.shape_class(g3)


def test_register_config_overrides():
    g = _geom()
    pinned = KernelConfig(bu=8, ba=2, bg=8, bab=2)
    tune.register_config(tune.shape_class(g), pinned)
    assert tune.get_config(g) is pinned


def test_autotune_off_tpu_returns_heuristic_and_caches():
    g = _geom()
    cfg = tune.autotune(g)
    assert isinstance(cfg, KernelConfig)
    assert tune.get_config(g) is cfg          # cached under the shape class


def test_pinned_config_produces_correct_kernels():
    g = _geom()
    tune.register_config(tune.shape_class(g), KernelConfig(bu=8, ba=3, bab=2))
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    out = ops.forward_project(f, g, "sf", backend="pallas")
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.forward(f, g, "sf")),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------- #
# Disk persistence (~/.cache/repro/tune.json by default; tests point
# REPRO_TUNE_CACHE_PATH at tmp via the conftest autouse fixture)
# --------------------------------------------------------------------------- #
def test_tune_cache_roundtrip(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE_PATH", str(path))
    g = _geom()
    key = tune.shape_class(g)
    cfg = KernelConfig(bu=32, ba=2, bg=32, bab=2, bs=2)
    tune.save_tuned(key, cfg)
    assert path.exists()
    assert tune.load_tuned(key) == cfg
    # a fresh process (cleared in-process registries) picks it up
    tune.clear()
    assert tune.get_config(g) == cfg
    # keyed by shape class: another class misses
    g2 = parallel_beam(6, 2, 500, VolumeGeometry(16, 16, 2))
    assert tune.load_tuned(tune.shape_class(g2)) is None


def test_tune_cache_escape_hatch(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE_PATH", str(path))
    monkeypatch.setenv("REPRO_TUNE_CACHE", "0")
    key = tune.shape_class(_geom())
    tune.save_tuned(key, KernelConfig(bu=32))
    assert not path.exists()                   # writes disabled
    assert tune.load_tuned(key) is None        # reads disabled too
    cfg = tune.get_config(_geom())             # falls back to heuristics
    assert cfg == tune.heuristic_config(_geom())


def test_tune_cache_corrupt_or_stale_file_ignored(tmp_path, monkeypatch):
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE_PATH", str(path))
    key = tune.shape_class(_geom())
    path.write_text("{not json")
    assert tune.load_tuned(key) is None
    # a stale schema (bad field values) is ignored, then overwritten cleanly
    path.write_text('{"%s": {"bu": "huge"}}' % tune._disk_key(key))
    assert tune.load_tuned(key) is None
    tune.save_tuned(key, KernelConfig(bu=16))
    assert tune.load_tuned(key) == KernelConfig(bu=16)


def test_tune_cache_pre_stripe_entry_still_loads(tmp_path, monkeypatch):
    """Entries written before the bs knob existed (no "bs" field) load with
    the field default instead of being discarded as stale."""
    path = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_TUNE_CACHE_PATH", str(path))
    key = tune.shape_class(_geom())
    path.write_text('{"%s": {"bu": 16, "bv": 128, "ba": 2, "bg": 16, '
                    '"bab": 2}}' % tune._disk_key(key))
    cfg = tune.load_tuned(key)
    assert cfg == KernelConfig(bu=16, bv=128, ba=2, bg=16, bab=2, bs=1)


# --------------------------------------------------------------------------- #
# Op cache: content-keyed, bounded, config round-trip
# --------------------------------------------------------------------------- #
def test_ops_cache_content_keyed():
    """Two distinct but equal geometry objects share one op entry."""
    fp1, _ = ops.get_ops(_geom(), "sf", "ref")
    fp2, _ = ops.get_ops(_geom(), "sf", "ref")
    assert fp1 is fp2


def test_ops_cache_bounded_eviction():
    ops.clear_cache()
    for i in range(ops._OPS_CACHE_SIZE + 40):
        g = parallel_beam(6, 2, 24, VolumeGeometry(16, 16, 2,
                                                   offset_x=1e-3 * (i + 1)))
        ops.get_ops(g, "sf", "ref")
    assert len(ops._OPS_CACHE) <= ops._OPS_CACHE_SIZE


def test_config_roundtrip_no_retrace():
    """Equal configs map to the same cached ops, so an outer jit never
    retraces; a different config is a different entry."""
    g = _geom()
    fp1, bp1 = ops.get_ops(g, "sf", "pallas", config=KernelConfig(ba=2))
    fp2, bp2 = ops.get_ops(g, "sf", "pallas", config=KernelConfig(ba=2))
    assert fp1 is fp2 and bp1 is bp2
    fp3, _ = ops.get_ops(g, "sf", "pallas", config=KernelConfig(ba=3))
    assert fp3 is not fp1


def test_dtype_keyed_config_reachable():
    """Configs registered for a non-f32 dtype class are found by the kernel
    entry points (the input dtype is threaded into resolution)."""
    g = _geom()
    pinned = KernelConfig(bu=8, ba=2)
    tune.register_config(tune.shape_class(g, 1, jnp.bfloat16), pinned)
    assert tune.get_config(g, dtype=jnp.bfloat16) is pinned
    assert tune.get_config(g) is not pinned
    from repro.kernels import fp_par
    f = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape).astype(
        jnp.bfloat16)
    seen = []
    orig = tune.get_config

    def spy(geom, batch=1, dtype=jnp.float32, **kw):
        seen.append(jnp.dtype(dtype).name)
        return orig(geom, batch=batch, dtype=dtype, **kw)

    tune.get_config = spy
    try:
        fp_par.fp_parallel_sf_pallas(f, g)
        ops.clear_cache()
        ops.forward_project(f, g, "sf", backend="pallas")   # dispatch path too
    finally:
        tune.get_config = orig
    assert seen.count("bfloat16") >= 2


def test_batched_dispatch_resolves_with_real_batch(monkeypatch):
    """The public dispatch path must resolve configs against the actual
    leading batch size (batch-aware shape classes), not batch=1."""
    g = _geom()
    calls = []
    orig = tune.get_config

    def spy(geom, batch=1, **kw):
        calls.append(batch)
        return orig(geom, batch=batch, **kw)

    monkeypatch.setattr(tune, "get_config", spy)
    ops.clear_cache()
    f = jax.random.normal(jax.random.PRNGKey(0), (8,) + g.vol.shape)
    out = ops.forward_project(f, g, "sf", backend="pallas")
    assert out.shape == (8,) + g.sino_shape
    assert 8 in calls


def test_ops_cache_dtype_keyed():
    """The op cache keys the dtype pair: compute_dtype variants and input
    dtypes get distinct bundles (a cdt=None bundle follows its input's
    dtype, so f32 and bf16 callers must never share traced closures)."""
    g = _geom()
    fp32, _ = ops.get_ops(g, "sf", "ref")
    fpb, _ = ops.get_ops(g, "sf", "ref", compute_dtype="bfloat16")
    assert fp32 is not fpb
    # alias normalizes into the same key
    fpb2, _ = ops.get_ops(g, "sf", "ref", compute_dtype="bf16")
    assert fpb is fpb2
    # input dtype is part of the content key even on the default-f32 path
    ops.clear_cache()
    f32 = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    out32 = ops.forward_project(f32, g, "sf", backend="pallas")
    n1 = len(ops._OPS_CACHE)
    out16 = ops.forward_project(f32.astype(jnp.bfloat16), g, "sf",
                                backend="pallas")
    assert len(ops._OPS_CACHE) == n1 + 1
    assert out32.dtype == jnp.float32 and out16.dtype == jnp.bfloat16


def test_projector_compute_dtype_roundtrip():
    """Projector(compute_dtype=...) reaches the kernels: bf16 tiles change
    the numerics measurably (vs the f32 run) while the output keeps the
    caller's f32 dtype; bad values raise at construction."""
    g = _geom()
    x = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    from repro.kernels import precision
    s32 = Projector(g, "sf", backend="pallas")(x)
    sb = Projector(g, "sf", backend="pallas", compute_dtype="bf16")(x)
    assert sb.dtype == jnp.float32
    rel = float(jnp.abs(sb - s32).max() / jnp.abs(s32).max())
    assert 0.0 < rel < precision.BF16_FP_REL_BOUND
    with pytest.raises(ValueError):
        Projector(g, compute_dtype="float64")


def test_projector_accepts_config():
    g = _geom()
    cfg = KernelConfig(bu=8, ba=2)
    proj = Projector(g, "sf", backend="pallas", config=cfg)
    x = jax.random.normal(jax.random.PRNGKey(0), g.vol.shape)
    y = jax.random.normal(jax.random.PRNGKey(1), g.sino_shape)
    lhs = jnp.vdot(proj(x), y)
    rhs = jnp.vdot(x, proj.T(y))
    assert abs(lhs - rhs) / max(abs(lhs), 1e-6) < 1e-4
    with pytest.raises(TypeError):
        Projector(g, "sf", config="big")      # not a KernelConfig


def test_fbp_accepts_config():
    from repro.core.fbp import fbp
    g = _geom()
    sino = jnp.ones(g.sino_shape)
    rec = fbp(sino, g, config=KernelConfig())
    assert rec.shape == g.vol.shape
